"""Quickstart: train a small LM on the synthetic stream, with checkpointing
and auto-resume — the whole framework in 40 lines.

    PYTHONPATH=src python examples/quickstart.py --steps 100
"""
import argparse

import jax

from repro.checkpoint import CheckpointManager
from repro.configs import get_config, reduce_config
from repro.data.pipeline import SyntheticLM
from repro.runtime import StragglerMonitor, TrainRunner
from repro.training import AdamWConfig, init_state, make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="olmo-1b")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_quickstart")
    args = ap.parse_args()

    cfg = reduce_config(get_config(args.arch)).with_(num_layers=2)
    opt = AdamWConfig(lr=3e-3, warmup_steps=10, total_steps=args.steps)
    state = init_state(cfg, opt, jax.random.PRNGKey(0))
    n = sum(x.size for x in jax.tree.leaves(state.params))
    print(f"arch={cfg.name} params={n:,}")

    step = jax.jit(make_train_step(cfg, opt), donate_argnums=0)
    data = SyntheticLM(cfg, batch=args.batch, seq=args.seq)
    runner = TrainRunner(step, data.batch_at,
                         CheckpointManager(args.ckpt_dir, keep_n=2),
                         ckpt_every=20, monitor=StragglerMonitor())
    state, report = runner.run(state, args.steps)
    print(f"steps={report.final_step} restarts={report.restarts} "
          f"loss {report.losses[0]:.3f} -> {report.losses[-1]:.3f}")


if __name__ == "__main__":
    main()
