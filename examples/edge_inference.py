"""The paper's scenario end to end: int8 (packed-data) transformer inference
through the CGRA block-GEMM path, validated against the fp32 reference and
costed on the 4x4 PE / 4x2 MOB array.

The whole model — q/k/v/o projections, MLP and LM head — runs through the
quantized GEMM stack (``quant="w8a8"`` + ``model.quantize_params``), not
just a single demo projection; ``kernel_mode="interpret"`` additionally
executes the exact Pallas kernel math on CPU.  The final section serves the
int8 model through the paged continuous-batching engine (``EngineConfig``),
the deployment shape the paper's accelerator targets.

    PYTHONPATH=src python examples/edge_inference.py
"""
import jax
import numpy as np

from repro.configs import get_config
from repro.core.cgra import CGRAConfig, simulate_transformer_layer
from repro.models import model as M
from repro.serving import Engine, EngineConfig, bytes_tokenizer_encode


def main():
    cfg = get_config("cgra-edge")
    params = M.init(cfg, jax.random.PRNGKey(0))
    B, S = 1, 32
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab_size)

    # fp32 reference logits
    hidden, _, _ = M.forward_hidden(cfg, params, {"tokens": tokens}, mode="train")
    logits_ref = M.lm_logits(cfg, params, hidden)

    # full w8a8 forward: weights int8-quantized once, every dense projection
    # and the LM head served through the packed int8 GEMM with fused dequant
    cfg_q = cfg.with_(quant="w8a8")
    params_q = M.quantize_params(cfg_q, params)
    hidden_q, _, _ = M.forward_hidden(cfg_q, params_q, {"tokens": tokens},
                                      mode="train")
    logits_q = M.lm_logits(cfg_q, params_q, hidden_q)
    rel = np.abs(np.asarray(logits_q) - np.asarray(logits_ref)) / (
        np.abs(np.asarray(logits_ref)) + 1.0)
    agree = float(np.mean(np.argmax(np.asarray(logits_q), -1)
                          == np.argmax(np.asarray(logits_ref), -1)))
    print(f"w8a8 full model: median rel err {np.median(rel):.4f}, "
          f"argmax agreement {agree:.3f}")

    # same quantized model through the Pallas kernels (interpret mode = the
    # exact kernel math, executed on CPU)
    cfg_qi = cfg_q.with_(kernel_mode="interpret")
    hidden_qi, _, _ = M.forward_hidden(cfg_qi, params_q, {"tokens": tokens},
                                       mode="train")
    logits_qi = M.lm_logits(cfg_qi, params_q, hidden_qi)
    dk = float(np.max(np.abs(np.asarray(logits_qi) - np.asarray(logits_q))))
    print(f"w8a8 Pallas-interpret vs jnp-int8 reference: max |dlogits| {dk:.2e}")

    # energy/latency budget on the paper's array
    cgra = CGRAConfig()
    tot, reps = simulate_transformer_layer(cgra, cfg.d_model, cfg.num_heads,
                                           cfg.head_dim, cfg.d_ff, seq=S)
    print(f"CGRA per-layer: {tot.time_us/1e3:.2f} ms, {tot.energy_pj/1e6:.1f} uJ, "
          f"{tot.power_mw:.2f} mW, PE util {tot.pe_utilization:.2f}")
    print(f"full {cfg.num_layers}-layer forward: "
          f"{cfg.num_layers*tot.time_us/1e3:.1f} ms @ ~{tot.power_mw:.1f} mW "
          f"-> edge-deployable (paper's ultra-low-power class)")
    for name, r in list(reps.items())[:3]:
        print(f"  {name:8s} cycles={r.cycles:8d} AI={r.arithmetic_intensity:5.1f} "
              f"util={r.pe_utilization:.2f}")

    # edge serving: the same int8 model behind the paged engine — requests
    # share KV pages for common prompt prefixes via the radix cache
    eng = Engine(cfg, params, EngineConfig(
        max_len=64, max_batch=2, page_size=16, quant="w8a8"))
    common = "edge transformer inference: "
    prompts = [bytes_tokenizer_encode(common + tail, cfg.vocab_size)
               for tail in ("keyword spotting", "wake word")]
    out, stats = eng.generate(prompts, max_new=8)
    print(f"served {len(out)} requests ({stats.tokens_out} tokens, "
          f"{stats.tokens_per_s:.1f} tok/s decode, "
          f"prefix_hit={eng.prefix_hit_rate:.0%}, "
          f"pages_used={eng.pool.num_used}/{eng.pool.n_pages - 1})")


if __name__ == "__main__":
    main()
