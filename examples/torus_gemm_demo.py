"""Distributed torus-scheduled GEMM demo (paper C3 at pod scale): the FFN of
a transformer layer computed with neighbor-only collective_permute rings on
an 8-device mesh, validated against the dense result, with the lowered
collective schedule printed.

    PYTHONPATH=src python examples/torus_gemm_demo.py
"""
import os

os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=8").strip()

import re  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402
from jax import shard_map  # noqa: E402
from jax.sharding import PartitionSpec as P  # noqa: E402

from repro.core import torus  # noqa: E402


def main():
    mesh = jax.make_mesh((8,), ("model",),
                         axis_types=(jax.sharding.AxisType.Auto,))
    rng = np.random.RandomState(0)
    B, S, D, F = 2, 64, 256, 1024
    x = jnp.asarray(rng.randn(B, S, D), jnp.float32)
    wg = jnp.asarray(rng.randn(D, F) * 0.05, jnp.float32)
    wu = jnp.asarray(rng.randn(D, F) * 0.05, jnp.float32)
    wd = jnp.asarray(rng.randn(F, D) * 0.05, jnp.float32)

    y = torus.torus_ffn(x, wg, wu, wd, mesh)
    ref = (np.asarray(jax.nn.silu(x @ wg)) * np.asarray(x @ wu)) @ np.asarray(wd)
    print("torus FFN allclose:", np.allclose(np.asarray(y), ref, atol=1e-3))

    # show the collective schedule: neighbor permutes only
    f = shard_map(lambda xs, ws: torus.ring_allgather_matmul(xs, ws),
                  mesh=mesh, in_specs=(P("model", None), P(None, "model")),
                  out_specs=P(None, "model"))
    txt = jax.jit(f).lower(
        jax.ShapeDtypeStruct((S, D), jnp.float32),
        jax.ShapeDtypeStruct((D, F), jnp.float32)).compile().as_text()
    counts = {k: len(re.findall(k, txt))
              for k in ("collective-permute", "all-gather", "all-reduce")}
    print("ring AG-matmul HLO collectives:", counts)
    srcdst = re.findall(r"source_target_pairs=\{([^}]*)\}", txt)
    if srcdst:
        print("first permute pairs (neighbor ring):", srcdst[0][:60], "...")


if __name__ == "__main__":
    main()
