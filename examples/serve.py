"""End-to-end serving driver: batched requests through prefill + decode with
a growable KV cache (the same serve_step the dry-run lowers at pod scale).

    PYTHONPATH=src python examples/serve.py --arch gemma3-4b --max-new 24
"""
import argparse

import jax

from repro.configs import get_config, reduce_config
from repro.models import model as M
from repro.serving.engine import (Engine, bytes_tokenizer_decode,
                                  bytes_tokenizer_encode)

REQUESTS = [
    "the paper proposes a 4x4 PE array",
    "switchless mesh torus interconnects reduce",
    "block-wise GEMM execution increases data reuse",
    "ultra low power edge inference",
]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="olmo-1b")
    ap.add_argument("--max-new", type=int, default=24)
    ap.add_argument("--temperature", type=float, default=0.8)
    args = ap.parse_args()

    cfg = reduce_config(get_config(args.arch))
    params = M.init(cfg, jax.random.PRNGKey(0))
    eng = Engine(cfg, params)

    prompts = [bytes_tokenizer_encode(r, cfg.vocab_size) for r in REQUESTS]
    out, stats = eng.generate(prompts, max_new=args.max_new,
                              temperature=args.temperature)
    print(f"arch={cfg.name} batch={len(prompts)} prefill={stats.prefill_s:.2f}s "
          f"decode={stats.decode_s:.2f}s ({stats.tokens_per_s:.1f} tok/s)")
    for req, seq in zip(REQUESTS, out):
        gen = bytes_tokenizer_decode(seq[len(bytes_tokenizer_encode(req, cfg.vocab_size)):])
        print(f"  [{req[:40]:40s}] -> {gen!r}")


if __name__ == "__main__":
    main()
