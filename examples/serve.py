"""End-to-end serving example: requests stream through the continuous-batching
engine — each is prefilled into pages of the shared KV pool, decodes inside
the scanned multi-token loop, and releases its pages for the next arrival
(common prompt prefixes share pages via the radix cache).

    PYTHONPATH=src python examples/serve.py --arch gemma3-4b --max-new 24
"""
import argparse

import jax

from repro.configs import get_config, reduce_config
from repro.models import model as M
from repro.serving import (Engine, EngineConfig, bytes_tokenizer_decode,
                           bytes_tokenizer_encode)

REQUESTS = [
    "the paper proposes a 4x4 PE array",
    "switchless mesh torus interconnects reduce",
    "block-wise GEMM execution increases data reuse",
    "ultra low power edge inference",
]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="olmo-1b")
    ap.add_argument("--max-new", type=int, default=24)
    ap.add_argument("--temperature", type=float, default=0.8)
    ap.add_argument("--batch", type=int, default=2,
                    help="max concurrent sequences (decode batch)")
    ap.add_argument("--chunk-tokens", type=int, default=None,
                    help="chunked-prefill budget per tick (default: whole "
                         "prompt in one chunk)")
    ap.add_argument("--kernel-mode", default=None,
                    choices=["reference", "interpret", "pallas"])
    ap.add_argument("--quant", default=None, choices=["none", "w8a8"],
                    help="w8a8: serve through the packed int8 GEMM kernels")
    ap.add_argument("--deadline", type=float, default=None,
                    help="per-request deadline (s); late requests retire "
                         "with finish_reason=deadline")
    ap.add_argument("--preemption", default="off",
                    choices=["off", "recompute", "drop"],
                    help="page-pressure policy (see EngineConfig.preemption)")
    args = ap.parse_args()

    cfg = reduce_config(get_config(args.arch))
    params = M.init(cfg, jax.random.PRNGKey(0))
    # batch of 2 for 4 requests: watch the engine recycle pages mid-flight;
    # the `with` block retires anything unfinished as CANCELLED and checks
    # the page pool reconciles on the way out
    with Engine(cfg, params, EngineConfig(
            max_len=256, max_batch=args.batch,
            chunk_tokens=args.chunk_tokens, deadline_s=args.deadline,
            preemption=args.preemption,
            kernel_mode=args.kernel_mode, quant=args.quant)) as eng:
        for i, req in enumerate(REQUESTS):
            eng.submit(bytes_tokenizer_encode(req, cfg.vocab_size),
                       max_new=args.max_new, temperature=args.temperature,
                       seed=i)
        results = {r.rid: r for r in eng.run()}

    stats = eng.stats
    print(f"arch={cfg.name} kernel_mode={eng.cfg.kernel_mode} "
          f"quant={eng.cfg.quant} requests={len(REQUESTS)} batch={args.batch} "
          f"prefill={stats.prefill_s:.2f}s decode={stats.decode_s:.2f}s "
          f"({stats.tokens_per_s:.1f} tok/s, "
          f"prefix_hit={eng.prefix_hit_rate:.0%})")
    for rid, req in enumerate(REQUESTS):
        r = results[rid]
        gen = bytes_tokenizer_decode(r.generated)
        print(f"  [{req[:40]:40s}] ({r.finish_reason.value}) -> {gen!r}")


if __name__ == "__main__":
    main()
