"""Seeded chaos suite: the deterministic fault-injection harness driving
the serving engine's degraded paths (DESIGN.md §10).

The headline property throughout: under injected faults the engine never
loses a request silently, its paging state reconciles, and the *survivors'*
greedy outputs are bit-identical to a fault-free run — and because every
fault fires from a seed/schedule, each scenario here is exactly
reproducible (asserted by replaying one storm twice).
"""
import jax
import numpy as np
import pytest

from repro.configs import get_config, reduce_config
from repro.models import model as M
from repro.serving import (FAULT_POINTS, ChaosError, ChaosInjector, Engine,
                           EngineConfig, FinishReason)
from repro.serving.paging import check_invariants


@pytest.fixture(scope="module")
def olmo():
    cfg = reduce_config(get_config("olmo-1b"))
    params = M.init(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _econ(**kw):
    kw.setdefault("max_len", 96)
    kw.setdefault("page_size", 16)
    kw.setdefault("decode_chunk", 4)
    return EngineConfig(**kw)


def _drain(eng):
    results = []
    while eng.num_queued or eng.num_active:
        results.extend(eng.step())
    results.extend(eng.run())
    return {r.rid: r for r in results}


def _reconciled(eng):
    bad = check_invariants(eng.pool, eng.radix, tables=eng.sched.owned)
    assert not bad, bad
    return True


# ---------------------------------------------------------------------------
# Injector unit behavior (no engine)
# ---------------------------------------------------------------------------

def test_injector_schedule_rates_and_clock():
    with pytest.raises(ValueError):
        ChaosInjector(schedule={"no.such.point": {0}})
    ch = ChaosInjector(seed=7, schedule={"pool.alloc": {1, 3}},
                       rates={"logits.nan": 0.5}, skew_s=10.0)
    assert [ch.fire("pool.alloc") for _ in range(5)] == [
        False, True, False, True, False]
    assert ch.count("pool.alloc") == 2
    with pytest.raises(ValueError):
        ch.fire("bogus")
    # rates are seeded per point: identical seeds replay identically
    a = [ChaosInjector(seed=7, rates={"logits.nan": 0.5}).fire("logits.nan")
         for _ in range(1)]
    b = [ChaosInjector(seed=7, rates={"logits.nan": 0.5}).fire("logits.nan")
         for _ in range(1)]
    assert a == b
    # the injected clock only moves when clock.skew fires
    before = ch.now()
    assert not ch.fire("clock.skew")  # not scheduled, no rate
    ch.schedule["clock.skew"] = frozenset({1})
    assert ch.fire("clock.skew")
    assert ch.now() - before >= 10.0
    assert ("clock.skew", 1) in ch.events


def test_failure_injector_is_a_chaos_specialization():
    from repro.runtime.ft import FailureInjector
    inj = FailureInjector(fail_at={3})
    assert isinstance(inj, ChaosInjector)
    inj.maybe_fail(2)
    with pytest.raises(RuntimeError, match="injected node failure at step 3"):
        inj.maybe_fail(3)
    inj.maybe_fail(3)  # each step fires at most once (restart re-traversal)
    assert ("train.step", 3) in inj.events
    with pytest.raises(ValueError):
        inj.fire("pool.alloc")  # serving points are not in its catalog


# ---------------------------------------------------------------------------
# Transient faults: outputs bit-identical to a fault-free run
# ---------------------------------------------------------------------------

def _run(cfg, params, prompts, max_new=10, chaos=None, **ekw):
    eng = Engine(cfg, params, _econ(**ekw), chaos=chaos)
    rids = [eng.submit(p, max_new=max_new) for p in prompts]
    res = _drain(eng)
    assert _reconciled(eng)
    return eng, [res[r] for r in rids]


def test_pool_alloc_faults_are_survived(olmo):
    """Transient pool.alloc failures (admission rollback + growth retries,
    preemption as the backstop): every request still completes and greedy
    outputs match the fault-free run bit for bit."""
    cfg, params = olmo
    rng = np.random.RandomState(0)
    prompts = [rng.randint(1, cfg.vocab_size, 16).tolist() for _ in range(3)]
    kw = dict(max_batch=2, prefix_cache=False, preemption="recompute")
    _, want = _run(cfg, params, prompts, **kw)
    chaos = ChaosInjector(seed=11, rates={"pool.alloc": 0.3})
    eng, got = _run(cfg, params, prompts, chaos=chaos, **kw)
    assert chaos.count("pool.alloc") > 0  # the storm actually fired
    for w, g in zip(want, got):
        assert g.ok and g.generated == w.generated


def test_mixed_tick_transient_failures_retry(olmo):
    """runner.mixed failures are raised pre-dispatch, absorbed by step(),
    and the tick retries: results are unchanged, just later."""
    cfg, params = olmo
    rng = np.random.RandomState(1)
    prompts = [rng.randint(1, cfg.vocab_size, 20).tolist() for _ in range(2)]
    kw = dict(max_batch=2, chunk_tokens=8, prefix_cache=False)
    _, want = _run(cfg, params, prompts, **kw)
    chaos = ChaosInjector(schedule={"runner.mixed": {0, 2, 3}})
    eng, got = _run(cfg, params, prompts, chaos=chaos, **kw)
    assert chaos.count("runner.mixed") == 3
    for w, g in zip(want, got):
        assert g.ok and g.generated == w.generated


def test_chaos_error_escapes_nothing(olmo):
    """A scheduled runner.mixed fault on every consult still terminates:
    submit + close() under a 100% transient-failure storm."""
    cfg, params = olmo
    chaos = ChaosInjector(rates={"runner.mixed": 1.0})
    eng = Engine(cfg, params, _econ(max_batch=1), chaos=chaos)
    eng.submit(list(range(1, 9)), max_new=4)
    for _ in range(5):
        eng.step()  # every tick is injected-failed; nothing dispatches
    assert eng.stats.tokens_out == 0
    res = eng.close()
    assert [r.finish_reason for r in res] == [FinishReason.CANCELLED]


# ---------------------------------------------------------------------------
# Preempt/resume under radix COW sharing
# ---------------------------------------------------------------------------

def test_preempt_resume_with_shared_prefix_pages(olmo):
    """Recompute-preemption with radix sharing live: preempted requests
    resume through prefix hits on pages their siblings still share, and
    outputs stay bit-identical to an unpressured run."""
    cfg, params = olmo
    rng = np.random.RandomState(2)
    prefix = rng.randint(1, cfg.vocab_size, 32).tolist()
    prompts = [prefix + rng.randint(1, cfg.vocab_size, 4).tolist()
               for _ in range(3)]
    kw = dict(max_batch=3, prefix_cache=True)
    _, want = _run(cfg, params, prompts, max_new=16, **kw)
    eng, got = _run(cfg, params, prompts, max_new=16, n_pages=8,
                    preemption="recompute", **kw)
    assert eng.stats.preempted >= 1  # the small pool actually preempted
    assert eng.prefix_hit_rate > 0.0
    for w, g in zip(want, got):
        assert g.ok and g.generated == w.generated


# ---------------------------------------------------------------------------
# The seeded storm: everything at once, twice, bit-identical
# ---------------------------------------------------------------------------

def _storm(cfg, params, seed):
    rng = np.random.RandomState(3)  # same workload both runs
    prompts = [rng.randint(1, cfg.vocab_size, 16).tolist() for _ in range(5)]
    chaos = ChaosInjector(
        seed=seed,
        rates={"pool.alloc": 0.15, "runner.mixed": 0.15, "logits.nan": 0.1},
        schedule={"clock.skew": {25}}, skew_s=30.0)
    eng = Engine(cfg, params,
                 _econ(max_batch=2, n_pages=6, max_queue=3,
                       prefix_cache=False, preemption="recompute"),
                 chaos=chaos)
    rids = [eng.submit(p, max_new=8, deadline_s=60.0) for p in prompts]
    res = _drain(eng)
    assert _reconciled(eng)
    assert set(res) == set(rids)  # no request lost, none invented
    leftover = eng.close()
    assert leftover == []
    return ([(r, res[r].finish_reason, tuple(res[r].generated))
             for r in rids], list(chaos.events), eng.stats)


def test_seeded_storm_is_deterministic_and_lossless(olmo):
    cfg, params = olmo
    out1, events1, stats1 = _storm(cfg, params, seed=123)
    out2, events2, stats2 = _storm(cfg, params, seed=123)
    assert out1 == out2
    assert events1 == events2 and len(events1) > 0
    assert (stats1.preempted, stats1.rejected, stats1.deadline_expired,
            stats1.faults_isolated) == \
           (stats2.preempted, stats2.rejected, stats2.deadline_expired,
            stats2.faults_isolated)
    # a different seed draws a different storm (rates actually consult RNG)
    out3, events3, _ = _storm(cfg, params, seed=124)
    assert events3 != events1
    # every exit is a catalogued FinishReason; faults only where injected
    assert {r[1] for r in out1} <= set(FinishReason)
    if stats1.faults_isolated == 0:
        assert all(r[1] != FinishReason.FAULT for r in out1)


def test_fault_points_catalog_is_closed():
    """The catalog the engine consults is exactly the documented one — a
    new fault point must be added here and in DESIGN.md §10 together."""
    assert FAULT_POINTS == ("pool.alloc", "runner.mixed", "logits.nan",
                            "clock.skew")
    assert issubclass(ChaosError, RuntimeError)
