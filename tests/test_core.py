"""CGRA analytical simulator: paper claims C1-C4 hold in the model, plus
tile-mapper invariants and quantization/compression correctness."""
import jax.numpy as jnp
import numpy as np
from _prop import given, settings, st  # hypothesis, or deterministic fallback

from repro.core.cgra import (CGRAConfig, MXU_DIM, select_block_shapes,
                             simulate_gemm, simulate_transformer_layer)
from repro.core.quant import compress_grad, dequantize, quantize


CFG = CGRAConfig()


def test_c4_blocking_increases_reuse_and_cuts_traffic():
    b = simulate_gemm(CFG, 256, 256, 256, "int8", blocked=True)
    n = simulate_gemm(CFG, 256, 256, 256, "int8", blocked=False)
    assert b.loads_words < n.loads_words / 2
    assert b.arithmetic_intensity > 4 * n.arithmetic_intensity
    assert b.macs == n.macs  # same math


def test_c2_mob_decoupling_cuts_stalls():
    dec = simulate_gemm(CFG, 256, 256, 256, "int8")
    ser = simulate_gemm(CGRAConfig(decoupled_mob=False), 256, 256, 256, "int8")
    assert dec.cycles < ser.cycles
    assert dec.stall_cycles < ser.stall_cycles


def test_c3_switchless_torus_saves_energy_and_latency():
    t, _ = simulate_transformer_layer(CFG, 256, 4, 64, 1024, seq=128)
    s, _ = simulate_transformer_layer(CGRAConfig(switched_noc=True),
                                      256, 4, 64, 1024, seq=128)
    assert s.energy_pj > t.energy_pj
    assert s.cycles >= t.cycles


def test_c1_pe_array_throughput_scales():
    small = simulate_gemm(CGRAConfig(pe_rows=2, pe_cols=2), 512, 512, 512, "int8")
    big = simulate_gemm(CGRAConfig(pe_rows=8, pe_cols=8), 512, 512, 512, "int8")
    assert big.compute_cycles * 15 < small.compute_cycles * 16


def test_ultra_low_power_class():
    """The edge config stays in the paper's ultra-low-power class (mW-scale,
    not watts) while sustaining useful GEMM throughput."""
    r = simulate_gemm(CFG, 128, 256, 128, "int8")
    assert r.power_mw < 10.0
    assert r.pe_utilization > 0.5


@settings(max_examples=20, deadline=None)
@given(m=st.integers(16, 2048), k=st.integers(16, 2048), n=st.integers(16, 2048))
def test_prop_tile_mapper_fits_vmem(m, k, n):
    bm, bk, bn = select_block_shapes(m, k, n, dtype_bytes=2)
    assert bm % MXU_DIM == bk % MXU_DIM == bn % MXU_DIM == 0
    assert 2 * (bm * bk + bk * bn) * 2 + bm * bn * 4 <= 8 * 1024 * 1024


@settings(max_examples=20, deadline=None)
@given(m=st.integers(1, 512), k=st.integers(1, 512), n=st.integers(1, 512),
       blocked=st.booleans())
def test_prop_simulator_conservation(m, k, n, blocked):
    """MACs invariant; cycles >= compute bound; energy positive."""
    r = simulate_gemm(CFG, m, k, n, "int8", blocked=blocked)
    assert r.macs == m * n * k
    assert r.cycles >= r.compute_cycles
    assert r.energy_pj > 0
    assert 0 < r.pe_utilization <= 1.0


def test_grad_compression_error_feedback_converges():
    """Error feedback makes the *accumulated* compressed signal track the
    true gradient sum."""
    rng = np.random.RandomState(0)
    g_true = jnp.asarray(rng.randn(64, 64) * 1e-3, jnp.float32)
    err = jnp.zeros_like(g_true)
    total = jnp.zeros_like(g_true)
    for _ in range(50):
        qt, err = compress_grad(g_true, err)
        total = total + dequantize(qt)
    np.testing.assert_allclose(np.asarray(total) / 50, np.asarray(g_true),
                               atol=np.abs(g_true).max() * 0.02)


def test_quantize_axis_none_scalar_scale():
    x = jnp.asarray(np.random.RandomState(1).randn(10, 10), jnp.float32)
    qt = quantize(x, axis=None)
    assert qt.scale.shape == ()
    assert np.abs(np.asarray(dequantize(qt) - x)).max() <= float(
        jnp.abs(x).max()) / 127 + 1e-6
