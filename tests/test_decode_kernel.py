"""Flash-decode kernel validation: Pallas (interpret=True) vs the jnp oracle
across cache layouts (linear/ring), GQA grouping, logit softcap, mismatched
qk/v head dims (MLA latent decode), and mixed per-slot positions/validity
bounds (``start``: sliding windows on linear/paged caches, drained slots),
plus semantic tests that pin the oracle itself against full attention over
the unrolled sequence (ring == sliding window; linear+start == lower-bound
exclusion) and the all-invalid-slot -> zeros contract.  The paged layout's
kernel/oracle parity lives in test_paging.py."""
import jax.numpy as jnp
import numpy as np
import pytest
from _prop import given, settings, st  # hypothesis, or deterministic fallback

from repro.kernels import ref
from repro.kernels.decode_attention import flash_decode
from repro.kernels.ops import attend_decode
from repro.models.layers import attend

RNG = np.random.RandomState(7)


def _qkv(B, H, K, S, d, dv=None):
    """Cache-native layout: k/v are [B, S, K, d] like the engine's slots."""
    dv = dv or d
    q = jnp.asarray(RNG.randn(B, H, d) * 0.3, jnp.float32)
    k = jnp.asarray(RNG.randn(B, S, K, d) * 0.3, jnp.float32)
    v = jnp.asarray(RNG.randn(B, S, K, dv) * 0.3, jnp.float32)
    return q, k, v


# ---------------------------------------------------------------------------
# kernel (interpret) vs oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,H,K,S,d,layout,softcap", [
    (2, 4, 4, 128, 32, "linear", 0.0),
    (3, 8, 2, 96, 32, "linear", 0.0),    # GQA 4:1, ragged S
    (2, 4, 1, 128, 32, "linear", 30.0),  # MQA + softcap
    (2, 4, 2, 64, 32, "ring", 0.0),      # sliding-window ring
    (3, 6, 2, 50, 16, "ring", 20.0),     # ragged ring + softcap
])
def test_flash_decode_matches_oracle(B, H, K, S, d, layout, softcap):
    q, k, v = _qkv(B, H, K, S, d)
    pos = jnp.asarray(RNG.randint(0, 2 * S, size=B), jnp.int32) \
        if layout == "ring" else jnp.asarray(RNG.randint(0, S, size=B))
    start = jnp.asarray(RNG.randint(0, 8, size=B), jnp.int32)
    want = ref.flash_decode_ref(q, k, v, pos, start, layout=layout,
                                softcap=softcap)
    got = flash_decode(q, k, v, pos, start, layout=layout, softcap=softcap,
                       bk=32, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-6, rtol=1e-5)


def test_flash_decode_mla_head_dims():
    """qk dim != v dim (weight-absorbed MLA: q=[latent|rope], v=latent)."""
    B, H, S, dqk, dv = 2, 8, 80, 48, 32
    q, k, v = _qkv(B, H, 1, S, dqk, dv)
    pos = jnp.asarray([11, 79], jnp.int32)
    scale = 0.17  # explicit MLA scale (dn + dr)**-0.5, not dqk**-0.5
    want = ref.flash_decode_ref(q, k, v, pos, None, scale=scale)
    got = flash_decode(q, k, v, pos, None, scale=scale, bk=32, interpret=True)
    assert got.shape == (B, H, dv)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-6, rtol=1e-5)


def test_flash_decode_fused_kv_operand():
    """The MLA dual-operand form: ONE fused [latent | k_rope] cache passed
    as both k and v, with ``dv`` narrowing the value read to the latent
    columns — must equal passing the slices explicitly."""
    B, H, S, kvr, dr = 2, 8, 72, 32, 16
    q = jnp.asarray(RNG.randn(B, H, kvr + dr) * 0.3, jnp.float32)
    kv = jnp.asarray(RNG.randn(B, S, 1, kvr + dr) * 0.3, jnp.float32)
    pos = jnp.asarray([7, 65], jnp.int32)
    start = jnp.asarray([3, 0], jnp.int32)
    got = flash_decode(q, kv, kv, pos, start, scale=0.11, dv=kvr, bk=32,
                       interpret=True)
    want = flash_decode(q, kv, kv[..., :kvr], pos, start, scale=0.11, bk=32,
                        interpret=True)
    assert got.shape == (B, H, kvr)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-6, rtol=1e-5)


def test_flash_decode_mixed_slot_states():
    """One batch, every slot in a different lifecycle state: fresh (pos ==
    start), mid-sequence, at capacity, and fully empty (start > pos, the
    recycled-slot case) — the empty slot must return exact zeros."""
    B, H, K, S, d = 4, 4, 2, 64, 32
    q, k, v = _qkv(B, H, K, S, d)
    pos = jnp.asarray([5, 30, 63, 0], jnp.int32)
    start = jnp.asarray([5, 2, 0, 10], jnp.int32)
    got = flash_decode(q, k, v, pos, start, bk=32, interpret=True)
    want = ref.flash_decode_ref(q, k, v, pos, start)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-6, rtol=1e-5)
    assert np.all(np.asarray(got[3]) == 0.0)  # all-invalid -> exact zeros
    # fresh slot attends exactly its single live row
    G = H // K
    want0 = np.asarray(v[0, 5])  # [K, d]
    np.testing.assert_allclose(np.asarray(got[0]).reshape(K, G, d),
                               np.broadcast_to(want0[:, None], (K, G, d)),
                               atol=2e-6)


def test_flash_decode_empty_slot_zero_ring():
    B, H, K, S, d = 2, 4, 2, 32, 16
    q, k, v = _qkv(B, H, K, S, d)
    pos = jnp.asarray([40, 3], jnp.int32)
    start = jnp.asarray([60, 0], jnp.int32)  # slot 0: start > pos -> empty
    got = flash_decode(q, k, v, pos, start, layout="ring", bk=16,
                       interpret=True)
    assert np.all(np.asarray(got[0]) == 0.0)
    want = ref.flash_decode_ref(q, k, v, pos, start, layout="ring")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-6)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_decode_dtypes(dtype):
    B, H, K, S, d = 2, 4, 2, 64, 32
    q = jnp.asarray(RNG.randn(B, H, d) * 0.3, dtype)
    k = jnp.asarray(RNG.randn(B, S, K, d) * 0.3, dtype)
    v = jnp.asarray(RNG.randn(B, S, K, d) * 0.3, dtype)
    pos = jnp.asarray([10, 50], jnp.int32)
    got = flash_decode(q, k, v, pos, None, bk=32, interpret=True)
    want = ref.flash_decode_ref(q, k, v, pos, None)
    assert got.dtype == dtype
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), atol=2e-2)


# ---------------------------------------------------------------------------
# oracle semantics vs full attention over the unrolled sequence
# ---------------------------------------------------------------------------

def _simulate_cache(keys, vals, pos, S, layout):
    """Write keys/vals[0..pos] into a [S] cache the way the decode path does
    (linear at row t, ring at row t % S); cache-native [1, S, K, d]."""
    K = keys.shape[1]
    k_c = np.zeros((1, S, K, keys.shape[-1]), np.float32)
    v_c = np.zeros((1, S, K, vals.shape[-1]), np.float32)
    for t in range(pos + 1):
        row = t % S if layout == "ring" else t
        k_c[0, row] = keys[t]
        v_c[0, row] = vals[t]
    return jnp.asarray(k_c), jnp.asarray(v_c)


@pytest.mark.parametrize("layout,S,pos,start", [
    ("linear", 64, 40, 0), ("linear", 64, 40, 7),  # left-pad exclusion
    ("ring", 32, 20, 0), ("ring", 32, 50, 0),      # before / after wrap
    ("ring", 32, 50, 30),                          # pads still inside window
])
def test_decode_oracle_matches_unrolled_attend(layout, S, pos, start):
    """flash_decode over a simulated slot cache == `attend` (the model's jnp
    core) over the unrolled live sequence: causal single query at the end,
    window = ring size for the ring layout, pad rows dropped via start."""
    H, K, d = 4, 2, 16
    L = pos + 1
    keys = RNG.randn(L, K, d).astype(np.float32) * 0.3
    vals = RNG.randn(L, K, d).astype(np.float32) * 0.3
    q = jnp.asarray(RNG.randn(1, H, d) * 0.3, jnp.float32)
    k_c, v_c = _simulate_cache(keys, vals, pos, S, layout)
    got = flash_decode(q, k_c, v_c, jnp.int32(pos), jnp.int32(start),
                       layout=layout, bk=16, interpret=True)
    # oracle: attend over rows [start, pos] (with the ring keeping only the
    # last S of them), query at position pos
    lo = start if layout == "linear" else max(start, pos + 1 - S)
    kk = jnp.asarray(keys[lo:])[None]  # [1, T, K, d]
    vv = jnp.asarray(vals[lo:])[None]
    qq = q[:, None]  # [1, 1, H, d]
    p_q = jnp.asarray([pos])
    p_k = jnp.arange(lo, pos + 1)
    want = attend(qq, kk, vv, p_q, p_k, causal=True)  # [1, 1, H, d]
    np.testing.assert_allclose(np.asarray(got), np.asarray(want[:, 0]),
                               atol=2e-5, rtol=1e-4)


# ---------------------------------------------------------------------------
# ops dispatch + property sweep
# ---------------------------------------------------------------------------

def test_attend_decode_mode_dispatch():
    B, H, K, S, d = 2, 4, 2, 48, 16
    q, k, v = _qkv(B, H, K, S, d)
    pos = jnp.asarray([9, 33], jnp.int32)
    start = jnp.asarray([2, 0], jnp.int32)
    a = attend_decode(q, k, v, pos, start, mode="reference")
    b = attend_decode(q, k, v, pos, start, mode="interpret", bk=16)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               atol=2e-6, rtol=1e-5)


@settings(max_examples=10, deadline=None)
@given(s=st.sampled_from([16, 33, 64]), p=st.integers(0, 80),
       st_=st.integers(0, 12))
def test_prop_flash_decode_any_state(s, p, st_):
    q, k, v = _qkv(2, 4, 2, s, 16)
    pos = jnp.asarray([p % s, p], jnp.int32)
    start = jnp.asarray([st_, st_ // 2], jnp.int32)
    for layout in ("linear", "ring"):
        got = flash_decode(q, k, v, pos, start, layout=layout, bk=16,
                           interpret=True)
        want = ref.flash_decode_ref(q, k, v, pos, start, layout=layout)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=2e-6, rtol=1e-5, err_msg=layout)
