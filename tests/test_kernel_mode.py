"""kernel_mode / w8a8 wiring through the model hot path.

With ``kernel_mode="interpret"`` every dense projection runs through the
Pallas block-GEMM and forward/prefill attention through the Pallas flash
kernel (interpreted on CPU — the exact kernel math), so these tests pin the
whole integration: config -> layers.dense_proj / dispatch_attend ->
kernels.ops -> Pallas.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduce_config
from repro.core.quant import QTensor
from repro.models import model as M

ATOL = 1e-4


@pytest.fixture(scope="module")
def edge():
    cfg = get_config("cgra-edge")
    params = M.init(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, 32), 0,
                              cfg.vocab_size)
    return cfg, params, {"tokens": toks}


def test_interpret_forward_matches_reference(edge):
    cfg, params, batch = edge
    h_ref, _, _ = M.forward_hidden(cfg, params, batch, mode="train")
    lg_ref = M.lm_logits(cfg, params, h_ref)
    cfg_i = cfg.with_(kernel_mode="interpret")
    h_i, _, _ = M.forward_hidden(cfg_i, params, batch, mode="train")
    lg_i = M.lm_logits(cfg_i, params, h_i)
    np.testing.assert_allclose(np.asarray(lg_i), np.asarray(lg_ref), atol=ATOL)


def test_interpret_prefill_matches_reference(edge):
    cfg, params, batch = edge
    lg_ref, caches_ref = M.prefill(cfg, params, batch)
    lg_i, caches_i = M.prefill(cfg.with_(kernel_mode="interpret"), params,
                               batch)
    np.testing.assert_allclose(np.asarray(lg_i), np.asarray(lg_ref), atol=ATOL)
    for a, b in zip(jax.tree.leaves(caches_ref), jax.tree.leaves(caches_i)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=ATOL)


def test_interpret_ragged_prompt_lengths(edge):
    """Non-block-multiple S must run without divisibility assertions."""
    cfg, params, _ = edge
    cfg_i = cfg.with_(kernel_mode="interpret")
    for S in (7, 33):
        toks = jax.random.randint(jax.random.PRNGKey(S), (1, S), 0,
                                  cfg.vocab_size)
        lg_ref, _ = M.prefill(cfg, params, {"tokens": toks})
        lg_i, _ = M.prefill(cfg_i, params, {"tokens": toks})
        np.testing.assert_allclose(np.asarray(lg_i), np.asarray(lg_ref),
                                   atol=ATOL, err_msg=f"S={S}")


def test_interpret_gemma_window_softcap():
    """Local/global interleave + sliding window + softcap through the flash
    kernel path, vs the reference path."""
    cfg = reduce_config(get_config("gemma3-4b")).with_(logit_softcap=30.0)
    params = M.init(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(2), (2, 48), 0,
                              cfg.vocab_size)
    h_ref, _, _ = M.forward_hidden(cfg, params, {"tokens": toks}, mode="train")
    h_i, _, _ = M.forward_hidden(cfg.with_(kernel_mode="interpret"), params,
                                 {"tokens": toks}, mode="train")
    np.testing.assert_allclose(np.asarray(h_i, np.float32),
                               np.asarray(h_ref, np.float32), atol=1e-3)


def test_interpret_decode_matches_reference(edge):
    """Decode hot path obeys kernel_mode: interpret-mode ``decode_step``
    (flash-decode Pallas kernel through the interpreter) matches the jnp
    reference to <= 1e-4 logits on the edge config, stepping from the same
    caches (capacity pre-padded via ``prefill(cache_len=...)``)."""
    cfg, params, batch = edge
    toks = batch["tokens"]
    plen = toks.shape[1] - 3
    _, caches = M.prefill(cfg, params, {"tokens": toks[:, :plen]},
                          cache_len=toks.shape[1])
    cfg_i = cfg.with_(kernel_mode="interpret")
    for step in range(3):
        lg_ref, caches_ref = M.decode_step(
            cfg, params, caches, toks[:, plen + step: plen + step + 1],
            jnp.int32(plen + step))
        lg_i, caches_i = M.decode_step(
            cfg_i, params, caches, toks[:, plen + step: plen + step + 1],
            jnp.int32(plen + step))
        np.testing.assert_allclose(np.asarray(lg_i), np.asarray(lg_ref),
                                   atol=ATOL, err_msg=f"step {step}")
        caches = caches_ref
    for a, b in zip(jax.tree.leaves(caches_ref), jax.tree.leaves(caches_i)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=ATOL)


def test_interpret_decode_matches_reference_mla():
    """Same decode parity through the weight-absorbed MLA path (latent-space
    flash decode with mismatched qk/v dims)."""
    cfg = reduce_config(get_config("minicpm3-4b"))
    assert cfg.use_mla
    params = M.init(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(4), (2, 24), 0,
                              cfg.vocab_size)
    _, caches = M.prefill(cfg, params, {"tokens": toks[:, :-1]}, cache_len=24)
    lg_ref, _ = M.decode_step(cfg, params, caches, toks[:, -1:],
                              jnp.int32(23))
    lg_i, _ = M.decode_step(cfg.with_(kernel_mode="interpret"), params,
                            caches, toks[:, -1:], jnp.int32(23))
    np.testing.assert_allclose(np.asarray(lg_i, np.float32),
                               np.asarray(lg_ref, np.float32), atol=1e-2)


def test_quantize_params_structure(edge):
    cfg, params, _ = edge
    qp = M.quantize_params(cfg, params)
    layer0 = qp["stages"][0]["0"]
    assert isinstance(layer0["mixer"]["wq"], QTensor)
    assert layer0["mixer"]["wq"].q.dtype == jnp.int8
    assert isinstance(qp["lm_head"], QTensor)
    # norms / embeddings untouched; idempotent on re-application
    assert not isinstance(qp["embed"], QTensor)
    assert not isinstance(layer0["norm1"]["scale"], QTensor)
    qp2 = M.quantize_params(cfg, qp)
    assert qp2["lm_head"] is qp["lm_head"]


def test_w8a8_forward_close_to_fp32(edge):
    """End-to-end int8 path stays within quantization error of fp32 and
    mostly agrees on argmax."""
    cfg, params, batch = edge
    h_ref, _, _ = M.forward_hidden(cfg, params, batch, mode="train")
    lg_ref = np.asarray(M.lm_logits(cfg, params, h_ref), np.float32)
    cfg_q = cfg.with_(quant="w8a8")
    qp = M.quantize_params(cfg_q, params)
    h_q, _, _ = M.forward_hidden(cfg_q, qp, batch, mode="train")
    lg_q = np.asarray(M.lm_logits(cfg_q, qp, h_q), np.float32)
    rel = np.abs(lg_q - lg_ref) / (np.abs(lg_ref) + 1.0)
    assert np.median(rel) < 0.05, np.median(rel)
    agree = np.mean(np.argmax(lg_q[:, :, : cfg.vocab_size], -1)
                    == np.argmax(lg_ref[:, :, : cfg.vocab_size], -1))
    assert agree > 0.7, agree


def test_w8a8_prefill_decode(edge):
    """Quantized weights flow through prefill + the decode-step cache path."""
    cfg, params, batch = edge
    cfg_q = cfg.with_(quant="w8a8")
    qp = M.quantize_params(cfg_q, params)
    toks = batch["tokens"]
    lg, caches = M.prefill(cfg_q, qp, {"tokens": toks[:, :-1]},
                           cache_len=toks.shape[1])
    lg2, _ = M.decode_step(cfg_q, qp, caches, toks[:, -1:],
                           jnp.int32(toks.shape[1] - 1))
    assert np.isfinite(np.asarray(lg2, np.float32)).all()


def test_w8a8_tied_embeddings_head_quantized():
    """Tied-head configs (gemma) get an int8 copy of embed.T for the LM head
    GEMM — the embedding table itself stays float (it is a gather)."""
    cfg = reduce_config(get_config("gemma3-4b")).with_(quant="w8a8")
    assert cfg.tie_embeddings
    params = M.init(cfg, jax.random.PRNGKey(0))
    qp = M.quantize_params(cfg, params)
    assert isinstance(qp["lm_head_q"], QTensor)
    assert not isinstance(qp["embed"], QTensor)
    toks = jax.random.randint(jax.random.PRNGKey(3), (1, 16), 0,
                              cfg.vocab_size)
    h, _, _ = M.forward_hidden(cfg, qp, {"tokens": toks}, mode="train")
    lg = M.lm_logits(cfg, qp, h)
    assert np.isfinite(np.asarray(lg, np.float32)).all()
    # float path (no lm_head_q) still works for tied configs
    lg_f = M.lm_logits(cfg.with_(quant="none"), params, h)
    assert lg_f.shape == lg.shape
