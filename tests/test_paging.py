"""Paged-KV building blocks: PagePool alloc/free/refcount invariants, the
radix prefix cache (match/insert/evict, COW on divergence, hit accounting),
and paged flash-decode parity — the oracle's page-gather against the dense
linear layout, and the Pallas paged kernel (interpret) against the oracle."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.decode_attention import flash_decode
from repro.serving import PagePool, RadixCache

RNG = np.random.RandomState(11)


# ---------------------------------------------------------------------------
# page pool
# ---------------------------------------------------------------------------

def test_pool_alloc_until_exhausted():
    pool = PagePool(5)  # pages 1..4 usable, 0 is the trash page
    assert pool.num_free == 4 and pool.num_used == 0
    got = [pool.alloc() for _ in range(4)]
    assert sorted(got) == [1, 2, 3, 4]  # trash page never handed out
    assert all(pool.refcount(p) == 1 for p in got)
    assert pool.alloc() is None  # exhausted -> None, not an exception
    assert pool.num_free == 0 and pool.num_used == 4


def test_pool_free_via_decref_and_reuse():
    pool = PagePool(3)
    a = pool.alloc()
    b = pool.alloc()
    pool.decref(a)
    assert pool.num_free == 1
    c = pool.alloc()
    assert c == a  # freed page is reusable
    assert pool.refcount(b) == 1 and pool.refcount(c) == 1


def test_pool_refcount_sharing():
    pool = PagePool(4)
    p = pool.alloc()
    pool.incref(p)  # second holder (e.g. the radix tree)
    pool.incref(p)  # third
    assert pool.refcount(p) == 3
    pool.decref(p)
    pool.decref(p)
    assert pool.num_free == 2  # still held once: not freed
    pool.decref(p)
    assert pool.num_free == 3  # last ref -> back on the free list


def test_pool_trash_page_pinned():
    pool = PagePool(2)
    assert pool.refcount(0) == 1  # pinned forever
    with pytest.raises(AssertionError):
        pool.decref(0)
    with pytest.raises(AssertionError):
        pool.incref(0)
    with pytest.raises(ValueError):
        PagePool(1)  # no usable pages


def test_pool_double_free_is_detected():
    pool = PagePool(3)
    p = pool.alloc()
    pool.decref(p)
    with pytest.raises(AssertionError):
        pool.decref(p)


# ---------------------------------------------------------------------------
# radix prefix cache
# ---------------------------------------------------------------------------

def _cache(ps=4, n_pages=32):
    pool = PagePool(n_pages)
    return RadixCache(ps, pool), pool


def _insert_prompt(rc, pool, tokens):
    """Simulate an admission: alloc a page per full chunk, insert."""
    ps = rc.page_size
    pages = [pool.alloc() for _ in range(len(tokens) // ps)]
    rc.insert(tokens, pages)
    return pages


def test_radix_miss_then_full_hit():
    rc, pool = _cache()
    prompt = list(range(12))  # 3 full pages of 4
    m = rc.match(prompt)
    assert m.tokens == 0 and not m.full_pages and m.partial is None
    pages = _insert_prompt(rc, pool, prompt)
    assert all(pool.refcount(p) == 2 for p in pages)  # seq + tree
    m = rc.match(prompt)
    assert m.full_pages == pages and m.tokens == 12 and m.partial is None
    # accounting: 0/12 then 12/12 matched
    assert rc.lookup_tokens == 24 and rc.hit_tokens == 12
    assert rc.hit_rate == 0.5


def test_radix_max_match_caps_the_hit():
    """Engines cap at plen - 1 so at least one token remains to prefill."""
    rc, pool = _cache()
    prompt = list(range(8))
    _insert_prompt(rc, pool, prompt)
    m = rc.match(prompt, max_match=7)
    assert len(m.full_pages) == 1  # second page would need all 8 tokens
    assert m.partial is not None and m.partial[1] == 3  # 3-row COW share
    assert m.tokens == 7


def test_radix_partial_page_cow_on_divergence():
    """A prompt diverging inside a cached page shares it copy-on-write:
    match returns the donor page + the number of identical leading rows."""
    rc, pool = _cache()
    donor_pages = _insert_prompt(rc, pool, [1, 2, 3, 4, 5, 6, 7, 8])
    m = rc.match([1, 2, 3, 4, 5, 6, 99, 100])  # diverges at row 2 of page 2
    assert m.full_pages == donor_pages[:1]
    assert m.partial == (donor_pages[1], 2)
    assert m.tokens == 6
    # divergence at row 0 of the first page: nothing shareable
    m = rc.match([9, 9, 9, 9])
    assert m.tokens == 0 and m.partial is None


def test_radix_insert_existing_chunks_no_double_incref():
    rc, pool = _cache()
    prompt = [1, 2, 3, 4, 5, 6, 7, 8]
    pages = _insert_prompt(rc, pool, prompt)
    again = [pool.alloc(), pool.alloc()]
    assert rc.insert(prompt, again) == 0  # all chunks already cached
    assert all(pool.refcount(p) == 2 for p in pages)
    assert all(pool.refcount(p) == 1 for p in again)  # untouched


def test_radix_evict_lru_leaves_first():
    rc, pool = _cache(ps=4, n_pages=6)  # 5 usable pages
    a = _insert_prompt(rc, pool, [1, 2, 3, 4, 5, 6, 7, 8])  # chain a1 -> a2
    b = _insert_prompt(rc, pool, [9, 9, 9, 9])
    for p in a + b:
        pool.decref(p)  # sequences retire; only the tree holds the pages
    rc.match([1, 2, 3, 4, 5, 6, 7, 8])  # touch chain a: b becomes LRU
    assert pool.num_free == 2
    assert rc.evict(3) == 1  # b's leaf goes first
    assert pool.refcount(b[0]) == 0 and pool.refcount(a[1]) == 1
    # inner node a1 only becomes evictable after its leaf a2 goes
    assert rc.evict(5) == 2
    assert pool.num_free == 5


def test_radix_evict_skips_referenced_pages():
    rc, pool = _cache(ps=4, n_pages=4)
    pages = _insert_prompt(rc, pool, [1, 2, 3, 4])  # rc == 2: seq still live
    assert rc.evict(10) == 0  # nothing evictable
    assert pool.refcount(pages[0]) == 2
    pool.decref(pages[0])
    assert rc.evict(10) == 1  # now only the tree held it
    assert pool.num_free == 3


def test_radix_num_evictable_tracks_refs_and_structure():
    """num_evictable counts exactly what leaf-inward eviction can reach:
    tree-only pages whose whole subtree is also tree-only."""
    rc, pool = _cache(ps=4, n_pages=8)
    a = _insert_prompt(rc, pool, [1, 2, 3, 4, 5, 6, 7, 8])  # chain a1 -> a2
    b = _insert_prompt(rc, pool, [9, 9, 9, 9])
    assert rc.num_evictable() == 0  # every page still sequence-held
    pool.decref(b[0])
    assert rc.num_evictable() == 1
    pool.decref(a[1])  # leaf a2 tree-only, but inner a1 still held
    assert rc.num_evictable() == 2
    pool.decref(a[0])
    assert rc.num_evictable() == 3
    pool.incref(a[1])  # re-pin the leaf: a1 is unreachable again
    assert rc.num_evictable() == 1
    pool.decref(a[1])
    n = rc.num_evictable()
    assert rc.evict(10) == n == 3  # the count is exactly what evict frees
    assert rc.num_evictable() == 0


def test_radix_clear_releases_tree_refs():
    rc, pool = _cache()
    pages = _insert_prompt(rc, pool, list(range(8)))
    for p in pages:
        pool.decref(p)
    rc.clear()
    assert pool.num_free == 31
    assert rc.match(list(range(8))).tokens == 0


# ---------------------------------------------------------------------------
# paged flash decode: oracle gather == dense linear; kernel == oracle
# ---------------------------------------------------------------------------

def _paged_fixture(B=3, H=4, K=2, P=9, ps=8, d=16, dv=None, seed=3):
    """Page pools + tables + the equivalent dense [B, S, K, d] caches."""
    rng = np.random.RandomState(seed)
    dv = dv or d
    npp = (P - 1) // B  # pages per sequence (page 0 reserved)
    kp = rng.randn(P, ps, K, d).astype(np.float32) * 0.3
    vp = rng.randn(P, ps, K, dv).astype(np.float32) * 0.3
    # non-trivial tables: sequence b owns a scattered set of pages
    perm = rng.permutation(np.arange(1, P))[: B * npp].reshape(B, npp)
    kd = kp[perm].reshape(B, npp * ps, K, d)
    vd = vp[perm].reshape(B, npp * ps, K, dv)
    q = jnp.asarray(rng.randn(B, H, d) * 0.3, jnp.float32)
    pos = jnp.asarray(rng.randint(0, npp * ps, B), jnp.int32)
    return (q, jnp.asarray(kp), jnp.asarray(vp), jnp.asarray(perm, jnp.int32),
            jnp.asarray(kd), jnp.asarray(vd), pos)


def test_paged_ref_equals_dense_linear():
    """The page table is pure indirection: the oracle over (pools, table)
    must equal the oracle over the densely gathered cache."""
    q, kp, vp, tbl, kd, vd, pos = _paged_fixture()
    want = ref.flash_decode_ref(q, kd, vd, pos, None, layout="linear")
    got = ref.flash_decode_ref(q, kp, vp, pos, None, pages=tbl)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-6, rtol=1e-6)


@pytest.mark.parametrize("softcap,with_start", [(0.0, False), (25.0, True)])
def test_paged_kernel_matches_oracle(softcap, with_start):
    q, kp, vp, tbl, _, _, pos = _paged_fixture()
    start = (jnp.minimum(pos, jnp.asarray([3, 0, 11], jnp.int32))
             if with_start else None)
    want = ref.flash_decode_ref(q, kp, vp, pos, start, pages=tbl,
                                softcap=softcap)
    got = flash_decode(q, kp, vp, pos, start, pages=tbl, softcap=softcap,
                       interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-6, rtol=1e-5)


def test_paged_kernel_window_via_start():
    """Sliding windows under paging express validity as start = pos - w + 1
    over logical rows (no ring) — must equal the dense ring-free oracle
    restricted to the window."""
    q, kp, vp, tbl, kd, vd, pos = _paged_fixture(seed=5)
    w = 10
    start = jnp.maximum(pos - w + 1, 0)
    got = flash_decode(q, kp, vp, pos, start, pages=tbl, interpret=True)
    want = ref.flash_decode_ref(q, kd, vd, pos, start, layout="linear")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-6, rtol=1e-5)


def test_paged_kernel_mla_fused_operand():
    """MLA's dual-operand form through the paged path: one fused
    [latent | rope] pool passed as both k and v with dv narrowing."""
    B, H, P, ps, kvr, dr = 2, 8, 7, 8, 32, 16
    rng = np.random.RandomState(9)
    q = jnp.asarray(rng.randn(B, H, kvr + dr) * 0.3, jnp.float32)
    kv = jnp.asarray(rng.randn(P, ps, 1, kvr + dr) * 0.3, jnp.float32)
    tbl = jnp.asarray([[1, 2, 3], [4, 5, 6]], jnp.int32)
    pos = jnp.asarray([7, 20], jnp.int32)
    got = flash_decode(q, kv, kv, pos, None, pages=tbl, scale=0.13, dv=kvr,
                       interpret=True)
    want = ref.flash_decode_ref(q, kv, kv, pos, None, pages=tbl, scale=0.13,
                                dv=kvr)
    assert got.shape == (B, H, kvr)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-6, rtol=1e-5)


def test_paged_kernel_empty_and_fresh_slots():
    """Retired slots (table all trash-page zeros, pos=0, start>pos) return
    exact zeros; a fresh slot attends exactly its single live row."""
    B, H, K, P, ps, d = 2, 4, 2, 5, 8, 16
    rng = np.random.RandomState(13)
    q = jnp.asarray(rng.randn(B, H, d) * 0.3, jnp.float32)
    kp = jnp.asarray(rng.randn(P, ps, K, d) * 0.3, jnp.float32)
    vp = jnp.asarray(rng.randn(P, ps, K, d) * 0.3, jnp.float32)
    tbl = jnp.asarray([[0, 0], [1, 2]], jnp.int32)
    pos = jnp.asarray([0, 0], jnp.int32)
    start = jnp.asarray([1, 0], jnp.int32)  # slot 0: start > pos -> empty
    got = flash_decode(q, kp, vp, pos, start, pages=tbl, interpret=True)
    assert np.all(np.asarray(got[0]) == 0.0)
    G = H // K
    want1 = np.asarray(vp[1, 0])  # [K, d]: page 1, row 0
    np.testing.assert_allclose(np.asarray(got[1]).reshape(K, G, d),
                               np.broadcast_to(want1[:, None], (K, G, d)),
                               atol=2e-6)
