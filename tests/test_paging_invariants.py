"""Property checks for ``paging.check_invariants``: the structural
invariants hold at every quiescent point of randomized allocate / share /
publish / evict workloads, and ``num_evictable`` responds monotonically to
external references."""
import random

import pytest

from _prop import given, settings, st
from repro.serving.paging import PagePool, RadixCache, check_invariants


def assert_healthy(pool, radix=None, tables=None, step=""):
    bad = check_invariants(pool, radix, tables)
    assert bad == [], f"after {step}: {bad}"


def _tokens(rng, n):
    return [rng.randrange(50) for _ in range(n)]


@given(seed=st.integers(min_value=0, max_value=10 ** 6))
@settings(max_examples=8, deadline=None)
def test_invariants_hold_across_random_workloads(seed):
    rng = random.Random(seed)
    ps = 4
    pool = PagePool(rng.randrange(6, 24))
    radix = RadixCache(ps, pool)
    tables: list[list[int]] = []
    prompts: dict[int, list[int]] = {}  # id(table) -> its token prefix

    for step in range(40):
        op = rng.choice(["admit", "retire", "evict", "clear"])
        if op == "admit":
            n_pages = rng.randrange(1, 4)
            toks = _tokens(rng, n_pages * ps)
            m = radix.match(toks, max_match=len(toks) - 1)
            for pid in m.full_pages:
                pool.incref(pid)
            fresh = []
            need = n_pages - len(m.full_pages)
            if pool.num_free + radix.num_evictable() >= need:
                radix.evict(need)
            for _ in range(need):
                pid = pool.alloc()
                if pid is None:
                    break
                fresh.append(pid)
            table = list(m.full_pages) + fresh
            if len(table) == n_pages:
                # prefill "completed": publish the full pages
                radix.insert(toks[: len(table) * ps], table)
                tables.append(table)
                prompts[id(table)] = toks
            else:  # admission failed: roll back every reference taken
                for pid in table:
                    pool.decref(pid)
        elif op == "retire" and tables:
            table = tables.pop(rng.randrange(len(tables)))
            prompts.pop(id(table))
            for pid in table:
                pool.decref(pid)
        elif op == "evict":
            radix.evict(rng.randrange(1, pool.n_pages))
        elif op == "clear" and rng.random() < 0.2:
            radix.clear()
        assert_healthy(pool, radix, tables, f"step {step} ({op})")

    for table in tables:
        for pid in table:
            pool.decref(pid)
    radix.clear()
    assert_healthy(pool, radix, [], "teardown")
    assert pool.num_used == 0


@given(seed=st.integers(min_value=0, max_value=10 ** 6))
@settings(max_examples=8, deadline=None)
def test_num_evictable_monotone_under_external_refs(seed):
    """An external reference on a tree page can only shrink the evictable
    set; releasing it restores the count exactly."""
    rng = random.Random(seed)
    ps = 2
    pool = PagePool(16)
    radix = RadixCache(ps, pool)
    pages = []
    for _ in range(rng.randrange(2, 6)):
        toks = _tokens(rng, rng.randrange(1, 4) * ps)
        table = [pool.alloc() for _ in range(len(toks) // ps)]
        radix.insert(toks, table)
        pages.extend(table)
        for pid in table:  # owner retires; only the tree holds the pages
            pool.decref(pid)
    tree_pages = [p for p in set(pages) if pool.refcount(p) == 1]
    if not tree_pages:
        return
    ev0 = radix.num_evictable()
    assert 0 < ev0 <= len(tree_pages)
    pid = rng.choice(tree_pages)
    pool.incref(pid)
    ev1 = radix.num_evictable()
    assert ev1 <= ev0
    pool.decref(pid)
    assert radix.num_evictable() == ev0
    assert_healthy(pool, radix, [], "monotonicity probe")


def test_trash_page_is_never_freed():
    pool = PagePool(4)
    with pytest.raises(AssertionError):
        pool.decref(0)
    for _ in range(3):
        assert pool.alloc() != 0
    assert pool.alloc() is None  # exhausted without ever touching page 0
    assert_healthy(pool, step="exhaustion")
