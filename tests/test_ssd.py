"""Mamba-2 SSD: chunked forward vs naive sequential recurrence, decode
streaming consistency, and chunk-size invariance."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduce_config
from repro.models import ssd as S
from repro.models.params import init_params

F32 = jnp.float32


def setup(chunk=8, seed=0):
    cfg = reduce_config(get_config("mamba2-130m")).with_(ssm_chunk=chunk)
    p = init_params(S.ssd_specs(cfg), jax.random.PRNGKey(seed), jnp.float32)
    return cfg, p


def naive_recurrence(cfg, p, x):
    """Sequential state-space recurrence (the decode path applied per step)."""
    B, Sq, D = x.shape
    cache = {
        "h": jnp.zeros((B, cfg.ssm_heads, cfg.ssm_headdim, cfg.ssm_state), F32),
        "conv_x": jnp.zeros((B, cfg.ssm_conv_width - 1, cfg.ssm_heads, cfg.ssm_headdim)),
        "conv_B": jnp.zeros((B, cfg.ssm_conv_width - 1, cfg.ssm_state)),
        "conv_C": jnp.zeros((B, cfg.ssm_conv_width - 1, cfg.ssm_state)),
    }
    outs = []
    for t in range(Sq):
        y, cache = S.ssd_decode(cfg, p, cache, x[:, t : t + 1])
        outs.append(y)
    return jnp.concatenate(outs, axis=1), cache


@pytest.mark.parametrize("chunk", [4, 8, 16])
def test_chunked_equals_naive(chunk):
    cfg, p = setup(chunk)
    x = 0.3 * jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model), F32)
    fast = S.ssd_forward(cfg, p, x)
    slow, _ = naive_recurrence(cfg, p, x)
    np.testing.assert_allclose(np.asarray(fast), np.asarray(slow),
                               atol=1e-3, rtol=1e-2)


def test_chunk_size_invariance():
    cfg4, p = setup(4)
    cfg16 = cfg4.with_(ssm_chunk=16)
    x = 0.3 * jax.random.normal(jax.random.PRNGKey(2), (1, 16, cfg4.d_model), F32)
    np.testing.assert_allclose(np.asarray(S.ssd_forward(cfg4, p, x)),
                               np.asarray(S.ssd_forward(cfg16, p, x)),
                               atol=1e-3, rtol=1e-2)


def test_prefill_cache_continues_stream():
    """forward(x, return_cache) then decode(x_new) == forward(concat)."""
    cfg, p = setup(8)
    x = 0.3 * jax.random.normal(jax.random.PRNGKey(3), (1, 24, cfg.d_model), F32)
    full = S.ssd_forward(cfg, p, x)
    out16, cache = S.ssd_forward(cfg, p, x[:, :16], return_cache=True)
    y17, cache = S.ssd_decode(cfg, p, cache, x[:, 16:17])
    np.testing.assert_allclose(np.asarray(y17[:, 0]), np.asarray(full[:, 16]),
                               atol=1e-3, rtol=1e-2)


def test_state_decay_bounded():
    """|h| stays bounded (A < 0 guarantees decay)."""
    cfg, p = setup(8)
    x = jax.random.normal(jax.random.PRNGKey(4), (1, 128, cfg.d_model), F32)
    _, cache = S.ssd_forward(cfg, p, x, return_cache=True)
    assert np.isfinite(np.asarray(cache["h"])).all()
