"""Torus ring-collective schedules vs dense references.

Multi-device tests run in a subprocess with
``--xla_force_host_platform_device_count=8`` so the main pytest process keeps
its single-device view (per the dry-run isolation rule)."""
import os
import subprocess
import sys
import textwrap

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    try:
        from jax.experimental.shard_map import shard_map
    except ImportError:  # newer jax moved it to the top level
        from jax import shard_map
    from jax.sharding import PartitionSpec as P
    from repro.core import torus
    from repro.launch.mesh import make_mesh

    mesh = make_mesh((8,), ("model",))
    rng = np.random.RandomState(0)
    T, D, F = 64, 32, 48
    x = rng.randn(T, D).astype(np.float32)
    w = rng.randn(D, F).astype(np.float32)

    f = shard_map(lambda xs, ws: torus.ring_allgather_matmul(xs, ws),
                  mesh=mesh, in_specs=(P("model", None), P(None, "model")),
                  out_specs=P(None, "model"))
    assert np.allclose(np.asarray(f(x, w)), x @ w, atol=1e-4), "AG-matmul"

    w2 = rng.randn(F, D).astype(np.float32)
    h = rng.randn(T, F).astype(np.float32)
    g = shard_map(lambda hs, ws: torus.matmul_reducescatter_ring(hs, ws),
                  mesh=mesh, in_specs=(P(None, "model"), P("model", None)),
                  out_specs=P("model", None))
    assert np.allclose(np.asarray(g(h, w2)), h @ w2, atol=1e-3), "MM-RS"

    vs = np.stack([rng.randn(33).astype(np.float32) for _ in range(8)])
    rr = shard_map(lambda a: torus.ring_allreduce(a[0])[None], mesh=mesh,
                   in_specs=(P("model", None),), out_specs=P("model", None))(vs)
    assert np.allclose(np.asarray(rr)[0], vs.sum(0), atol=1e-4), "ring-AR"

    B, S, D2, F2 = 2, 16, 32, 64
    x3 = rng.randn(B, S, D2).astype(np.float32)
    wg = rng.randn(D2, F2).astype(np.float32)
    wu = rng.randn(D2, F2).astype(np.float32)
    wd = rng.randn(F2, D2).astype(np.float32)
    yt = torus.torus_ffn(jnp.asarray(x3), wg, wu, wd, mesh)
    ref = (np.asarray(jax.nn.silu(x3 @ wg)) * (x3 @ wu)) @ wd
    assert np.allclose(np.asarray(yt), ref, atol=1e-3), "torus-FFN"

    # HLO check: ring schedules lower to collective-permute only (C3)
    xs = jax.ShapeDtypeStruct((T, D), jnp.float32)
    ws = jax.ShapeDtypeStruct((D, F), jnp.float32)
    txt = jax.jit(f).lower(x, w).compile().as_text()
    assert "collective-permute" in txt, "expected neighbor permutes"
    assert "all-gather" not in txt, "ring schedule must not all-gather"

    # int8-compressed cross-pod gradient mean (training/compress.py)
    from repro.training.compress import compressed_mean
    g8 = shard_map(lambda a: compressed_mean(a[0], "model")[0][None],
                   mesh=mesh, in_specs=(P("model", None),),
                   out_specs=P("model", None))
    vals = np.stack([np.full((257,), i, np.float32) for i in range(8)])
    got = np.asarray(g8(vals))[0]
    assert np.allclose(got, vals.mean(0), atol=vals.max() / 100), "compressed mean"
    print("TORUS-OK")
""")


@pytest.mark.slow
def test_torus_collectives_subprocess():
    env = dict(os.environ, PYTHONPATH=SRC)
    env.pop("XLA_FLAGS", None)
    res = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                         capture_output=True, text=True, timeout=600)
    assert "TORUS-OK" in res.stdout, res.stdout + res.stderr
