"""Recompilation budget: the engine's executable cache must match the
documented bound — chunked prefill compiles ONE mixed variant (``("mixed",
chunk_tokens)``), unchunked prefill at most one per power-of-two bucket, and
non-decomposable mixers one whole-prompt executable per distinct prompt
length.  A shape leak into any traced argument (e.g. keying on chunk offset
or prefix length) would show up here as extra cache entries."""
import math

import jax

from repro.configs import get_config, reduce_config
from repro.models import model as M
from repro.serving import Engine, EngineConfig

EC = dict(page_size=16, max_batch=2, max_len=64, decode_chunk=2)


def build(name, **kw):
    cfg = reduce_config(get_config(name))
    params = M.init(cfg, jax.random.PRNGKey(0))
    return Engine(cfg, params, EngineConfig(**EC, **kw))


def jit_cache_size(fn):
    get = getattr(fn, "_cache_size", None)
    return get() if get is not None else None


def serve(eng, lengths, max_new=3):
    prompts = [[(7 * i + j) % eng.cfg.vocab_size for j in range(n)]
               for i, n in enumerate(lengths)]
    out, _ = eng.generate(prompts, max_new=max_new)
    assert all(len(o) == n + max_new for o, n in zip(out, lengths))
    return out


def test_chunked_prefill_compiles_one_mixed_variant():
    eng = build("olmo-1b", chunk_tokens=4)
    serve(eng, [5, 9, 7, 12])
    assert set(eng.runner.fns) == {("mixed", 4)}
    cs = jit_cache_size(eng.runner.decode_fn)
    if cs is not None:
        assert cs == 1, "decode executable recompiled"


def test_unchunked_prefill_buckets_power_of_two():
    eng = build("olmo-1b", chunk_tokens=None)
    serve(eng, [5, 9, 7])  # suffixes bucket to 8, 16, 8
    keys = set(eng.runner.fns)
    assert keys == {("mixed", 8), ("mixed", 16)}
    for kind, C in keys:
        assert kind == "mixed" and C & (C - 1) == 0
    assert len(keys) <= int(math.log2(EC["max_len"])) + 1


def test_whole_prefill_one_executable_per_length():
    eng = build("mamba2-130m")  # SSM: not prefix-decomposable
    serve(eng, [5, 5, 7])
    assert set(eng.runner.fns) == {("whole", 5), ("whole", 7)}
    cs = jit_cache_size(eng.runner.decode_fn)
    if cs is not None:
        assert cs == 1


def test_repeat_traffic_adds_no_variants():
    eng = build("olmo-1b", chunk_tokens=4)
    serve(eng, [6, 10])
    before = dict(eng.runner.fns)
    serve(eng, [10, 6, 8])
    assert set(eng.runner.fns) == set(before)
    for key, fn in eng.runner.fns.items():
        assert fn is before[key], f"{key} was rebuilt"
