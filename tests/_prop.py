"""Property-test shim: re-export hypothesis when installed, otherwise a
minimal deterministic fallback so tier-1 collects and runs without network.

The fallback drives each ``@given`` test over a fixed pseudo-random sample of
the declared strategy space (seeded, so runs are reproducible).  It covers
only the strategy surface the suite uses: ``integers``, ``booleans``,
``sampled_from``.
"""
from __future__ import annotations

import random

__all__ = ["HAVE_HYPOTHESIS", "given", "settings", "st"]

try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

    _FALLBACK_EXAMPLES = 8

    class _Strategy:
        def __init__(self, draw):
            self._draw = draw

        def example(self, rng):
            return self._draw(rng)

    class st:  # noqa: N801 - mirrors the hypothesis module name
        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(lambda rng: rng.randint(min_value, max_value))

        @staticmethod
        def booleans():
            return _Strategy(lambda rng: rng.random() < 0.5)

        @staticmethod
        def sampled_from(items):
            seq = list(items)
            return _Strategy(lambda rng: seq[rng.randrange(len(seq))])

    def settings(*_a, **kw):
        max_examples = kw.get("max_examples")

        def deco(fn):
            if max_examples is not None:
                fn._prop_max_examples = max_examples
            return fn

        return deco

    def given(**strats):
        def deco(fn):
            # NB: no functools.wraps — pytest must not see the inner
            # signature, or it would resolve the drawn params as fixtures
            def wrapper(*args, **kwargs):
                n = getattr(wrapper, "_prop_max_examples", None) \
                    or _FALLBACK_EXAMPLES
                rng = random.Random(0xC68A)
                for _ in range(min(n, _FALLBACK_EXAMPLES)):
                    drawn = {k: s.example(rng) for k, s in strats.items()}
                    fn(*args, **drawn, **kwargs)

            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            return wrapper

        return deco
