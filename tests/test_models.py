"""Per-architecture smoke tests (reduced configs): one forward/train step on
CPU asserting output shapes + no NaNs, plus prefill->decode consistency
against the full forward pass (catches KV-cache/RoPE/ring-buffer bugs)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import REGISTRY, get_config, reduce_config
from repro.models import model as M

ARCHS = list(REGISTRY)


def make_batch(cfg, B=2, S=64, rng=None):
    rng = rng or jax.random.PRNGKey(0)
    batch = {}
    if cfg.audio_frontend:
        batch["frames"] = jax.random.normal(rng, (B, S, cfg.frontend_dim), jnp.float32)
    batch["tokens"] = jax.random.randint(rng, (B, S), 0, cfg.vocab_size)
    batch["labels"] = jax.random.randint(rng, (B, S), 0, cfg.vocab_size)
    if cfg.vision_tokens:
        batch["images"] = jax.random.normal(rng, (B, cfg.vision_tokens, cfg.vision_dim))
    return batch


@pytest.fixture(scope="module")
def built():
    out = {}
    for name in ARCHS:
        cfg = reduce_config(get_config(name))
        if cfg.num_experts:
            # full capacity: token drops are load-dependent, so prefill vs
            # decode consistency only holds when nothing is dropped
            cfg = cfg.with_(capacity_factor=float(cfg.num_experts))
        params = M.init(cfg, jax.random.PRNGKey(0))
        out[name] = (cfg, params)
    return out


@pytest.mark.parametrize("name", ARCHS)
def test_forward_loss_finite(built, name):
    cfg, params = built[name]
    batch = make_batch(cfg)
    loss, metrics = M.loss_fn(cfg, params, batch)
    assert np.isfinite(float(loss)), (name, loss)
    assert float(loss) > 0
    hidden, aux, _ = M.forward_hidden(cfg, params, batch, mode="train")
    assert hidden.shape == (2, 64, cfg.d_model)
    assert np.isfinite(np.asarray(hidden, np.float32)).all()


@pytest.mark.parametrize("name", ARCHS)
def test_grad_step_changes_params_finitely(built, name):
    cfg, params = built[name]
    batch = make_batch(cfg)
    grads = jax.grad(lambda p: M.loss_fn(cfg, p, batch)[0])(params)
    flat = jax.tree.leaves(grads)
    assert all(np.isfinite(np.asarray(g, np.float32)).all() for g in flat), name
    gnorm = sum(float(jnp.sum(jnp.square(g.astype(jnp.float32)))) for g in flat)
    assert gnorm > 0, f"{name}: zero gradient"


@pytest.mark.parametrize("name", [n for n in ARCHS
                                  if REGISTRY[n].kind == "decoder"])
def test_prefill_decode_matches_forward(built, name):
    """logits(prefill(x[:-1]) -> decode(x[-1])) == logits(forward(x))[-1]."""
    cfg, params = built[name]
    B, S = 2, 64
    batch = make_batch(cfg, B, S)
    # full forward logits at the last position
    hidden, _, _ = M.forward_hidden(cfg, params, batch, mode="prefill")
    full_logits = M.lm_logits(cfg, params, hidden[:, -1:])

    # prefill on S-1 tokens, then decode token S-1
    b2 = dict(batch)
    b2["tokens"] = batch["tokens"][:, : S - 1]
    _, caches = M.prefill(cfg, params, b2, cache_len=S)
    step_logits, _ = M.decode_step(cfg, params, caches,
                                   batch["tokens"][:, S - 1:],
                                   jnp.int32(S - 1))
    np.testing.assert_allclose(
        np.asarray(step_logits[:, 0], np.float32),
        np.asarray(full_logits[:, 0], np.float32), atol=2e-2, rtol=1e-2)


@pytest.mark.parametrize("name", [n for n in ARCHS
                                  if REGISTRY[n].kind == "decoder"])
def test_multi_step_decode_consistency(built, name):
    """Decoding tokens one by one reproduces teacher-forced full logits."""
    cfg, params = built[name]
    B, S, extra = 1, 48, 4
    batch = make_batch(cfg, B, S + extra)
    hidden, _, _ = M.forward_hidden(cfg, params, batch, mode="prefill")
    want = M.lm_logits(cfg, params, hidden)  # [B, S+extra, V]

    b2 = dict(batch)
    b2["tokens"] = batch["tokens"][:, :S]
    logits, caches = M.prefill(cfg, params, b2, cache_len=S + extra)
    np.testing.assert_allclose(np.asarray(logits[:, 0], np.float32),
                               np.asarray(want[:, S - 1], np.float32),
                               atol=2e-2, rtol=1e-2)
    for i in range(extra):
        logits, caches = M.decode_step(
            cfg, params, caches, batch["tokens"][:, S + i: S + i + 1],
            jnp.int32(S + i))
        np.testing.assert_allclose(np.asarray(logits[:, 0], np.float32),
                                   np.asarray(want[:, S + i], np.float32),
                                   atol=3e-2, rtol=1e-2, err_msg=f"{name} step {i}")


def test_sliding_window_ring_cache_wraps():
    """Decode far past the window: ring buffer must stay consistent."""
    cfg = reduce_config(get_config("gemma3-4b"))  # window = 32
    params = M.init(cfg, jax.random.PRNGKey(0))
    B, S, extra = 1, 60, 8  # prefill spans nearly 2 windows
    batch = make_batch(cfg, B, S + extra)
    hidden, _, _ = M.forward_hidden(cfg, params, batch, mode="prefill")
    want = M.lm_logits(cfg, params, hidden)
    b2 = {"tokens": batch["tokens"][:, :S]}
    logits, caches = M.prefill(cfg, params, b2, cache_len=S + extra)
    for i in range(extra):
        logits, caches = M.decode_step(cfg, params, caches,
                                       batch["tokens"][:, S + i: S + i + 1],
                                       jnp.int32(S + i))
        np.testing.assert_allclose(np.asarray(logits[:, 0], np.float32),
                                   np.asarray(want[:, S + i], np.float32),
                                   atol=3e-2, rtol=1e-2, err_msg=f"step {i}")


@pytest.mark.parametrize("name", ARCHS)
def test_param_count_full_config_sane(name):
    """Full (non-reduced) configs hit their advertised parameter class."""
    from repro.launch.roofline import active_params
    cfg = get_config(name)
    total, active = active_params(cfg)
    expected = {
        "gemma3-4b": (3e9, 6e9), "minicpm3-4b": (3e9, 6e9),
        "olmo-1b": (0.9e9, 2e9), "deepseek-67b": (60e9, 72e9),
        "jamba-v0.1-52b": (45e9, 60e9), "kimi-k2-1t-a32b": (0.9e12, 1.2e12),
        "qwen3-moe-30b-a3b": (28e9, 34e9), "mamba2-130m": (0.1e9, 0.2e9),
        "llama-3.2-vision-11b": (9e9, 13e9), "hubert-xlarge": (0.8e9, 1.3e9),
        "cgra-edge": (1e6, 3e8),
    }[name]
    assert expected[0] <= total <= expected[1], (name, total)
    assert active <= total


def test_unrolled_matches_scanned():
    """scan_layers=False (cost-compile path) is numerically identical."""
    cfg = reduce_config(get_config("deepseek-67b"))
    params = M.init(cfg, jax.random.PRNGKey(0))
    batch = make_batch(cfg)
    l1, _ = M.loss_fn(cfg, params, batch)
    l2, _ = M.loss_fn(cfg.with_(scan_layers=False), params, batch)
    np.testing.assert_allclose(float(l1), float(l2), rtol=1e-5)


def test_attn_chunking_matches_unchunked():
    cfg = reduce_config(get_config("olmo-1b"))
    params = M.init(cfg, jax.random.PRNGKey(0))
    batch = make_batch(cfg, B=2, S=64)
    l1, _ = M.loss_fn(cfg, params, batch)
    l2, _ = M.loss_fn(cfg, params, batch, attn_chunk=16)
    np.testing.assert_allclose(float(l1), float(l2), rtol=1e-4)
