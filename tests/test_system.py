"""End-to-end behaviour tests: training actually learns, serving generates,
sharding rules resolve, and the public API is coherent."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import SHAPES, cell_skip_reason, get_config, reduce_config
from repro.data.pipeline import SyntheticLM
from repro.models import model as M
from repro.serving.engine import Engine, bytes_tokenizer_encode
from repro.training import AdamWConfig, init_state, make_train_step


@pytest.mark.slow
def test_training_reduces_loss():
    """~60 steps on the synthetic induction stream must visibly learn."""
    cfg = reduce_config(get_config("olmo-1b")).with_(num_layers=2)
    opt = AdamWConfig(lr=3e-3, warmup_steps=5, total_steps=60, clip_norm=1.0)
    state = init_state(cfg, opt, jax.random.PRNGKey(0))
    step = jax.jit(make_train_step(cfg, opt), donate_argnums=0)
    data = SyntheticLM(cfg, batch=8, seq=64)
    losses = []
    for i in range(60):
        state, m = step(state, data.batch_at(i))
        losses.append(float(m["loss"]))
    first, last = np.mean(losses[:5]), np.mean(losses[-5:])
    assert last < first - 0.3, (first, last)


def test_engine_generates_batched():
    cfg = reduce_config(get_config("olmo-1b"))
    params = M.init(cfg, jax.random.PRNGKey(0))
    eng = Engine(cfg, params)
    prompts = [bytes_tokenizer_encode("hello world", cfg.vocab_size),
               bytes_tokenizer_encode("the quick brown fox", cfg.vocab_size)]
    out, stats = eng.generate(prompts, max_new=8)
    assert len(out) == 2
    assert len(out[0]) == len(prompts[0]) + 8
    assert all(0 <= t < cfg.vocab_size for seq in out for t in seq)
    assert stats.tokens_out == 16


def test_engine_sampling_temperature():
    cfg = reduce_config(get_config("olmo-1b"))
    params = M.init(cfg, jax.random.PRNGKey(0))
    eng = Engine(cfg, params)
    p = [bytes_tokenizer_encode("abc", cfg.vocab_size)]
    a, _ = eng.generate(p, max_new=16, temperature=1.0, seed=1)
    b, _ = eng.generate(p, max_new=16, temperature=1.0, seed=2)
    assert a != b  # different seeds sample differently


def test_cell_skip_reasons():
    assert cell_skip_reason(get_config("hubert-xlarge"), SHAPES["decode_32k"])
    assert cell_skip_reason(get_config("deepseek-67b"), SHAPES["long_500k"])
    assert cell_skip_reason(get_config("mamba2-130m"), SHAPES["long_500k"]) is None
    assert cell_skip_reason(get_config("jamba-v0.1-52b"), SHAPES["long_500k"]) is None
    assert cell_skip_reason(get_config("gemma3-4b"), SHAPES["long_500k"]) is None
    assert cell_skip_reason(get_config("olmo-1b"), SHAPES["train_4k"]) is None


def test_sharding_rules_resolve():
    from repro.launch.sharding import resolve_pspec
    from repro.models.params import ParamSpec

    class FakeMesh:
        shape = {"pod": 2, "data": 16, "model": 16}

    mesh = FakeMesh()
    # TP: ffn dim shards over model; FSDP picks embed over data
    ps = resolve_pspec(ParamSpec((8192, 22016), ("embed", "ffn")), mesh, fsdp=True)
    assert tuple(ps) == ("data", "model")
    # kv_heads=8 not divisible by 16 -> replicated, FSDP falls to embed
    ps = resolve_pspec(ParamSpec((8192, 8, 128), ("embed", "kv_heads", "qk")),
                       mesh, fsdp=True)
    assert tuple(ps) == ("data", None, None)
    # batch: graded fallback pod+data -> data -> none
    ps = resolve_pspec(ParamSpec((256, 4096), ("batch", None)), mesh)
    assert tuple(ps)[0] == ("pod", "data")
    ps = resolve_pspec(ParamSpec((16, 4096), ("batch", None)), mesh)
    assert tuple(ps)[0] == "data"
    ps = resolve_pspec(ParamSpec((1, 4096), ("batch", None)), mesh)
    assert all(a is None for a in tuple(ps))


def test_vocab_padding_loss_masked():
    """Padded vocab columns never receive probability mass."""
    cfg = reduce_config(get_config("olmo-1b")).with_(vocab_size=200, pad_vocab_to=64)
    assert cfg.padded_vocab == 256
    params = M.init(cfg, jax.random.PRNGKey(0))
    batch = {"tokens": jnp.zeros((1, 8), jnp.int32),
             "labels": jnp.ones((1, 8), jnp.int32)}
    loss, _ = M.loss_fn(cfg, params, batch)
    assert np.isfinite(float(loss))
    hidden, _, _ = M.forward_hidden(cfg, params, batch, mode="train")
    logits = M.lm_logits(cfg, params, hidden)
    assert logits.shape[-1] == 256


def test_input_specs_cover_all_cells():
    from repro.launch.cells import input_specs
    for name in ("gemma3-4b", "llama-3.2-vision-11b", "hubert-xlarge",
                 "mamba2-130m"):
        cfg = get_config(name)
        for shape in SHAPES.values():
            if cell_skip_reason(cfg, shape):
                continue
            specs = input_specs(cfg, shape)
            assert specs, (name, shape.name)
            if cfg.audio_frontend and shape.step != "decode":
                assert "frames" in specs
            if cfg.vision_tokens and shape.step != "decode":
                assert "images" in specs
