"""Chunked prefill + the unified mixed step: paged-past prefill attention
parity (oracle vs dense, interpret kernel vs oracle), engine greedy
bit-parity across ``chunk_tokens`` in {8, 32, None} (linear,
sliding-window, and interpret-mode cgra-edge configs), radix prefix hits
landing mid-chunk, decode retirement on the same tick a chunk runs,
``submit`` input validation, and the bounded mixed-step compile cache."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduce_config
from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention
from repro.models import model as M
from repro.serving import Engine, EngineConfig, bytes_tokenizer_encode


@pytest.fixture(scope="module")
def olmo():
    cfg = reduce_config(get_config("olmo-1b"))
    params = M.init(cfg, jax.random.PRNGKey(0))
    return cfg, params


@pytest.fixture(scope="module")
def gemma():
    cfg = reduce_config(get_config("gemma3-4b"))
    params = M.init(cfg, jax.random.PRNGKey(0))
    return cfg, params


@pytest.fixture(scope="module")
def edge():
    cfg = reduce_config(get_config("cgra-edge"))
    params = M.init(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _prompts(cfg, texts):
    return [bytes_tokenizer_encode(t, cfg.vocab_size) for t in texts]


def _econ(**kw):
    kw.setdefault("max_len", 96)
    kw.setdefault("page_size", 16)
    kw.setdefault("decode_chunk", 4)
    return EngineConfig(**kw)


def reference_greedy(cfg, params, prompt, max_new):
    """Unpaged exact-length whole-prompt loop — the oracle every chunked
    schedule must match bit for bit."""
    plen = len(prompt)
    logits, caches = M.prefill(cfg, params,
                               {"tokens": jnp.asarray([prompt], jnp.int32)},
                               cache_len=plen + max_new)
    cur = int(jnp.argmax(logits[0, -1, : cfg.vocab_size]))
    out = [cur]
    for step in range(max_new - 1):
        logits, caches = M.decode_step(cfg, params, caches,
                                       jnp.asarray([[cur]], jnp.int32),
                                       jnp.int32(plen + step))
        cur = int(jnp.argmax(logits[0, -1, : cfg.vocab_size]))
        out.append(cur)
    return out


# ---------------------------------------------------------------------------
# kernel: query-chunk attention over a paged past
# ---------------------------------------------------------------------------

def _rand_paged(seed=0, B=2, H=4, K=2, C=16, ps=16, npp=3, d=16):
    """Random page pools with shuffled per-sequence page tables; sequence 0
    starts its chunk mid-stream (a cached past), sequence 1 at position 0."""
    rng = np.random.RandomState(seed)
    P = 1 + B * npp  # page 0 reserved
    q = rng.randn(B, H, C, d).astype(np.float32)
    kp = rng.randn(P, ps, K, d).astype(np.float32)
    vp = rng.randn(P, ps, K, d).astype(np.float32)
    pages = np.zeros((B, npp), np.int32)
    for b in range(B):
        pages[b] = 1 + b * npp + rng.permutation(npp)
    q_start = np.array([ps + 3, 0], np.int32)[:B]
    k_len = q_start + C
    return (jnp.asarray(q), jnp.asarray(kp), jnp.asarray(vp),
            jnp.asarray(pages), jnp.asarray(q_start), jnp.asarray(k_len))


@pytest.mark.parametrize("window,softcap",
                         [(0, 0.0), (20, 0.0), (0, 15.0), (12, 9.0)])
def test_paged_prefill_oracle_matches_dense(window, softcap):
    """The paged-past oracle == per-sequence dense suffix-causal attention
    on the gathered pages (the alignment the engine's chunks rely on)."""
    q, kp, vp, pages, q_start, k_len = _rand_paged()
    out = ref.flash_attention_paged_ref(q, kp, vp, pages, q_start, k_len,
                                        window=window, softcap=softcap)
    B, H, C, d = q.shape
    G = H // kp.shape[2]
    for b in range(B):
        kd = kp[pages[b]].reshape(-1, *kp.shape[2:])[: int(k_len[b])]
        vd = vp[pages[b]].reshape(-1, *vp.shape[2:])[: int(k_len[b])]
        kb = jnp.repeat(kd.transpose(1, 0, 2), G, axis=0)[None]
        vb = jnp.repeat(vd.transpose(1, 0, 2), G, axis=0)[None]
        dense = ref.flash_attention_ref(q[b: b + 1], kb, vb, causal=True,
                                        window=window, softcap=softcap)
        np.testing.assert_allclose(out[b], dense[0], atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("window,softcap",
                         [(0, 0.0), (20, 0.0), (0, 15.0), (12, 9.0)])
def test_paged_prefill_kernel_matches_oracle(window, softcap):
    """Interpret-mode Pallas kernel (scalar-prefetch page-table index map,
    dead-block DMA elision) == the jnp oracle."""
    q, kp, vp, pages, q_start, k_len = _rand_paged(seed=1)
    want = ref.flash_attention_paged_ref(q, kp, vp, pages, q_start, k_len,
                                         window=window, softcap=softcap)
    got = flash_attention(q, kp, vp, pages=pages, q_start=q_start,
                          k_len=k_len, window=window, softcap=softcap,
                          interpret=True)
    np.testing.assert_allclose(got, want, atol=2e-5, rtol=2e-5)


def test_paged_prefill_kernel_shared_kv_and_tail():
    """k is v (MQA-style shared pool) and the chunk is shorter than the
    buffer: the valid rows still match the oracle."""
    q, kp, _, pages, q_start, k_len = _rand_paged(seed=2, B=1, K=1, H=2)
    n = 11  # valid chunk rows; the engine discards the rest
    want = ref.flash_attention_paged_ref(q, kp, kp, pages, q_start,
                                         q_start + n)
    got = flash_attention(q, kp, kp, pages=pages, q_start=q_start,
                          k_len=q_start + n, interpret=True)
    np.testing.assert_allclose(got[:, :, :n], want[:, :, :n],
                               atol=2e-5, rtol=2e-5)


# ---------------------------------------------------------------------------
# engine: chunked schedules are bit-identical to whole-prompt prefill
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("chunk_tokens", [8, 32, None])
def test_chunked_greedy_parity_linear(olmo, chunk_tokens):
    """Every chunk schedule — 8-token chunks, 32-token chunks, whole-suffix
    — produces the same greedy tokens as the unpaged whole-prompt loop."""
    cfg, params = olmo
    eng = Engine(cfg, params, _econ(max_batch=3, chunk_tokens=chunk_tokens))
    prompts = _prompts(cfg, ["hello world", "x",
                             "a prompt long enough to span several chunks"])
    out, stats = eng.generate(prompts, max_new=6)
    for p, seq in zip(prompts, out):
        assert seq[len(p):] == reference_greedy(cfg, params, p, 6)
    assert stats.prefills == 3


@pytest.mark.parametrize("chunk_tokens", [8, None])
def test_chunked_window_parity(gemma, chunk_tokens):
    """Sliding-window layers: chunks crossing the window boundary attend
    through the paged past with the same masking as whole-prompt prefill."""
    cfg, params = gemma
    assert cfg.window_size and cfg.local_global_pattern
    eng = Engine(cfg, params, _econ(max_len=128, max_batch=2,
                                    chunk_tokens=chunk_tokens))
    short = _prompts(cfg, ["tiny"])[0]                      # < window
    long = _prompts(cfg, ["w" * (cfg.window_size + 9)])[0]  # > window
    out, _ = eng.generate([short, long], max_new=6)
    for p, seq in zip([short, long], out):
        assert seq[len(p):] == reference_greedy(cfg, params, p, 6)


@pytest.mark.parametrize("chunk_tokens", [8, 32, None])
def test_chunked_interpret_parity_edge(edge, chunk_tokens):
    """cgra-edge in interpret mode: the chunked schedule runs the exact
    Pallas kernel math (paged prefill + paged decode), with a shared prefix
    exercising radix reuse inside a chunked prefill."""
    cfg, params = edge
    cfg_i = cfg.with_(kernel_mode="interpret")
    common = "shared edge prefix tokens: "  # 1 full 16-row page + COW tail
    prompts = _prompts(cfg, [common + "request one", common + "request two"])
    eng = Engine(cfg_i, params, _econ(max_len=64, max_batch=2,
                                      chunk_tokens=chunk_tokens))
    out, _ = eng.generate(prompts, max_new=4)
    assert eng.stats.prefix_hit_tokens >= 16
    for p, seq in zip(prompts, out):
        assert seq[len(p):] == reference_greedy(cfg_i, params, p, 4)


def test_radix_hit_lands_mid_chunk(olmo):
    """A prefix hit that is not chunk-aligned: the follow-up request starts
    prefilling at the matched offset (16 or 24 tokens into a 32-token chunk
    budget) and still matches the oracle token for token."""
    cfg, params = olmo
    rng = np.random.RandomState(7)
    base = rng.randint(1, cfg.vocab_size, 20).tolist()
    follow_full = base[:16] + rng.randint(1, cfg.vocab_size, 9).tolist()
    follow_cow = base[:10] + rng.randint(1, cfg.vocab_size, 7).tolist()
    eng = Engine(cfg, params, _econ(max_batch=2, chunk_tokens=32))
    out, _ = eng.generate([base], max_new=4)          # publishes 1 full page
    out2, _ = eng.generate([follow_full, follow_cow], max_new=4)
    # follow_full hits the whole published page (prefill starts at row 16);
    # follow_cow diverges mid-page (COW share, prefill starts at row 10)
    assert eng.stats.prefix_hit_tokens >= 16 + 10
    for p, seq in zip([follow_full, follow_cow], out2):
        assert seq[len(p):] == reference_greedy(cfg, params, p, 4)


def test_decode_retires_on_mixed_tick(olmo):
    """A decoding slot that exhausts its budget on a tick that also runs a
    prefill chunk retires that same tick, while the chunked prompt keeps
    prefilling — and both outputs match the oracle."""
    cfg, params = olmo
    eng = Engine(cfg, params, _econ(max_batch=2, chunk_tokens=8))
    short = _prompts(cfg, ["hi"])[0]
    long = _prompts(cfg, ["a sixty-ish byte prompt padded " + "y" * 30])[0]
    assert len(long) > 3 * 8  # several chunks
    ra = eng.submit(short, max_new=2)
    eng.step()  # short's prefill chunk completes; 1 decode token left
    rb = eng.submit(long, max_new=3)
    mixed = eng.step()  # long's first chunk + short's last decode step
    assert [r.rid for r in mixed] == [ra]
    assert eng.num_active == 1  # long still prefilling
    results = {r.rid: r for r in mixed}
    while eng.num_active or eng.num_queued:
        results.update({r.rid: r for r in eng.step()})
    assert results[ra].generated == reference_greedy(cfg, params, short, 2)
    assert results[rb].generated == reference_greedy(cfg, params, long, 3)


# ---------------------------------------------------------------------------
# submit validation + compile-cache bounds
# ---------------------------------------------------------------------------

def test_submit_validation(olmo):
    cfg, params = olmo
    eng = Engine(cfg, params, _econ(max_batch=1))
    with pytest.raises(ValueError, match="empty prompt"):
        eng.submit([], max_new=4)
    with pytest.raises(ValueError, match="max_new"):
        eng.submit([1, 2], max_new=0)
    with pytest.raises(ValueError, match="max_new"):
        eng.submit([1, 2], max_new=-3)
    with pytest.raises(ValueError, match="max_new"):
        eng.submit([1, 2], max_new=2.5)
    with pytest.raises(ValueError, match="tokens"):
        eng.submit([1, cfg.vocab_size], max_new=4)  # out of vocab
    with pytest.raises(ValueError, match="tokens"):
        eng.submit([1, -1], max_new=4)
    with pytest.raises(ValueError, match="tokens"):
        eng.submit([1, 2.5], max_new=4)  # non-integer token
    with pytest.raises(ValueError, match="temperature"):
        eng.submit([1, 2], max_new=4, temperature=-0.5)
    assert eng.num_queued == 0  # nothing malformed was admitted
    eng.submit([1, 2], max_new=4)
    assert len(eng.run()) == 1


def test_single_mixed_variant_under_chunking(olmo):
    """With ``chunk_tokens`` set, every prompt length shares ONE compiled
    mixed-step variant — the per-(prefix, suffix) prefill executable cache
    is gone for decomposable models."""
    cfg, params = olmo
    eng = Engine(cfg, params, _econ(max_batch=2, chunk_tokens=16))
    prompts = [list(range(1, 1 + n)) for n in (3, 17, 30, 41, 55)]
    out, _ = eng.generate(prompts, max_new=3)
    assert all(len(s) == len(p) + 3 for p, s in zip(prompts, out))
    assert set(eng._prefill_fns) == {("mixed", 16)}


def test_bucketed_variants_without_chunking(olmo):
    """Unchunked, whole-suffix chunks compile per power-of-two bucket, not
    per prompt length."""
    cfg, params = olmo
    eng = Engine(cfg, params, _econ(max_batch=2, prefix_cache=False))
    prompts = [list(range(1, 1 + n)) for n in (3, 5, 17, 30, 41)]
    eng.generate(prompts, max_new=3)
    assert set(eng._prefill_fns) == {("mixed", 8), ("mixed", 32),
                                     ("mixed", 64)}
