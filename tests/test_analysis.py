"""Mutation harness for ``repro.analysis``: every rule must *fire* on a
seeded defect (no dead rules) and stay silent on the healthy equivalent,
plus regression tests pinning the genuine findings the checker surfaced
(f32 logits contract, clamped paged index maps)."""
import dataclasses

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.analysis import (
    RULES,
    Report,
    check_donation,
    check_kernel_spec,
    check_logits_dtype,
    lint_hlo,
    lint_jaxpr,
    param_gather_shapes,
)
from repro.analysis.bounds import _GuardedTable
from repro.analysis.findings import Finding
from repro.kernels.spec import KernelSpec, OperandSpec, ScalarSpec
from repro.models import model as M
from repro.serving.paging import PagePool, RadixCache, check_invariants


def rules_of(findings):
    return {f.rule for f in findings}


def lint_of(fn, *args):
    return lint_jaxpr(jax.make_jaxpr(fn)(*args))


# ---------------------------------------------------------------------------
# J rules: jaxpr lints
# ---------------------------------------------------------------------------

def test_j001_fires_on_stray_int8_dequant():
    def bad(x):
        return x.astype(jnp.float32) * 2.0

    fs = lint_of(bad, jnp.zeros((4, 4), jnp.int8))
    assert rules_of(fs) == {"J001"}
    assert fs[0].file and "test_analysis" in fs[0].file  # provenance


def test_j001_allows_int8_to_int32():
    def ok(x):
        return x.astype(jnp.int32) + 1

    assert lint_of(ok, jnp.zeros((4, 4), jnp.int8)) == []


def test_j002_fires_on_unaccumulated_bf16_dot():
    def bad(a, b):
        return a @ b

    a = jnp.zeros((8, 8), jnp.bfloat16)
    assert "J002" in rules_of(lint_of(bad, a, a))


def test_j002_fires_on_int8_dot_without_int32():
    def bad(a, b):
        return jax.lax.dot_general(
            a, b, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    a = jnp.zeros((8, 8), jnp.int8)
    assert "J002" in rules_of(lint_of(bad, a, a))


def test_j002_silent_on_f32_accumulated_dot():
    def ok(a, b):
        return jax.lax.dot_general(
            a, b, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32).astype(jnp.bfloat16)

    a = jnp.zeros((8, 8), jnp.bfloat16)
    assert lint_of(ok, a, a) == []


def test_j003_fires_on_host_callback():
    def bad(x):
        return jax.pure_callback(
            lambda v: np.asarray(v), jax.ShapeDtypeStruct(x.shape, x.dtype), x)

    assert "J003" in rules_of(lint_of(bad, jnp.zeros(4)))


def test_j004_fires_on_large_baked_constant():
    big = jnp.asarray(np.ones((256, 256), np.float32))  # 256 KiB

    def bad(x):
        return x + big

    fs = lint_of(bad, jnp.zeros((256, 256), jnp.float32))
    assert "J004" in rules_of(fs)


def test_j005_fires_on_f64_leak():
    with jax.experimental.enable_x64():
        def bad(x):
            return x.astype(jnp.float64) * 2.0

        fs = lint_of(bad, jnp.zeros(4, jnp.float32))
    assert "J005" in rules_of(fs)


def test_j006_fires_on_bf16_logits():
    aval = jax.ShapeDtypeStruct((2, 1, 256), jnp.bfloat16)
    assert rules_of(check_logits_dtype(aval)) == {"J006"}
    ok = jax.ShapeDtypeStruct((2, 1, 256), jnp.float32)
    assert check_logits_dtype(ok) == []


# ---------------------------------------------------------------------------
# J007: compiled-HLO sharded-surface lint (pure text — no mesh needed)
# ---------------------------------------------------------------------------

_HLO_PARAM_GATHER = """
  %ag.1 = f32[128,16384]{1,0} all-gather(f32[128,2048]{1,0} %w), dimensions={1}
"""
_HLO_ACT_GATHER = """
  %ag.2 = f32[2,8,64]{2,1,0} all-gather(f32[2,1,64]{2,1,0} %x), dimensions={1}
"""
_HLO_HOST = """
  %cc = f32[4]{0} custom-call(f32[4]{0} %y), custom_call_target="SendToHost"
  %of = token[] outfeed(f32[4]{0} %z, token[] %tok)
"""


def test_j007_fires_on_full_param_all_gather():
    fs = lint_hlo(_HLO_PARAM_GATHER, {(128, 16384)})
    assert rules_of(fs) == {"J007"}
    assert "(128, 16384)" in fs[0].message


def test_j007_ignores_activation_all_gather():
    # the gathered shape matches no parameter leaf -> legitimate
    # activation collective, not a finding
    assert lint_hlo(_HLO_ACT_GATHER, {(128, 16384)}) == []


def test_j007_fires_on_host_transfers():
    fs = lint_hlo(_HLO_HOST, set())
    assert len(fs) == 2 and rules_of(fs) == {"J007"}
    msgs = " ".join(f.message for f in fs)
    assert "SendToHost" in msgs and "outfeed" in msgs


def test_j007_silent_on_clean_module():
    clean = """
  %dot = f32[64,64]{1,0} dot(f32[64,32]{1,0} %a, f32[32,64]{1,0} %b)
  %ar = f32[64,64]{1,0} all-reduce(f32[64,64]{1,0} %dot), to_apply=%sum
  %cc = f32[4]{0} custom-call(f32[4]{0} %y), \
custom_call_target="annotate_device_placement"
"""
    assert lint_hlo(clean, {(64, 64), (128, 16384)}) == []


def test_j007_dedupes_repeated_gathers():
    fs = lint_hlo(_HLO_PARAM_GATHER * 3, {(128, 16384)})
    assert len(fs) == 1


def test_param_gather_shapes_layer_slices():
    params = {"stacked": np.zeros((4, 128, 256), np.float32),
              "flat": np.zeros((256, 512), np.float32),
              "tiny": np.zeros((8,), np.float32)}
    shapes = param_gather_shapes(params)
    assert (4, 128, 256) in shapes        # full stacked leaf
    assert (128, 256) in shapes           # per-layer slice
    assert (256, 512) in shapes           # plain 2D leaf
    assert (8,) not in shapes             # below the size threshold


def test_j007_fires_on_real_sharded_mutation():
    """End-to-end on this host's devices: shard a weight over a 2-device
    mesh, then undo the placement with a replicate constraint — the SPMD
    partitioner must emit a full-parameter all-gather that J007 catches."""
    if jax.device_count() < 2:
        pytest.skip("needs >= 2 devices (multi-device CI lane)")
    from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

    mesh = Mesh(np.asarray(jax.devices()[:2]), ("model",))
    w = jax.device_put(jnp.zeros((128, 128), jnp.float32),
                       NamedSharding(mesh, P("model", None)))
    bad = jax.jit(lambda w: jax.lax.with_sharding_constraint(
        w, NamedSharding(mesh, P())) * 2.0)
    hlo = bad.lower(w).compile().as_text()
    fs = lint_hlo(hlo, param_gather_shapes({"w": w}))
    assert rules_of(fs) == {"J007"}


# ---------------------------------------------------------------------------
# D rules: donation
# ---------------------------------------------------------------------------

def test_d001_fires_on_dead_donation():
    def fn(a, b):
        return a + 0.0  # b's buffer matches no output

    args = (jnp.zeros((4,), jnp.float32), jnp.zeros((8,), jnp.float32))
    fs = check_donation(fn, args, (1,))
    assert rules_of(fs) == {"D001"}


def test_d002_fires_on_duplicate_donation():
    def fn(a, b):
        return a + b  # one output cannot absorb two donated buffers

    args = (jnp.zeros((4,), jnp.float32), jnp.zeros((4,), jnp.float32))
    fs = check_donation(fn, args, (0, 1))
    assert rules_of(fs) == {"D002"}


def test_donation_silent_on_absorbed_buffers():
    def fn(a, b):
        return a + b, b * 2.0

    args = (jnp.zeros((4,), jnp.float32), jnp.zeros((4,), jnp.float32))
    assert check_donation(fn, args, (0, 1)) == []


# ---------------------------------------------------------------------------
# K rules: BlockSpec bounds proofs
# ---------------------------------------------------------------------------

PAGES = ScalarSpec("pages", (2, 4), 0, 8)
POS = ScalarSpec("pos", (2,), 0, 32)


def test_k001_fires_on_unclamped_table_read():
    # pos reaches 32 (frozen slot) -> pos // 8 == 4 == table width: OOB
    spec = KernelSpec(
        "mut", (2, 4), scalars=(POS, PAGES),
        operands=(OperandSpec(
            "kv", (1, 8), lambda b, ik, pos_ref, pages_ref:
            (pages_ref[b, pos_ref[b] // 8], ik), (9, 4)),))
    fs = check_kernel_spec(spec)
    assert rules_of(fs) == {"K001"}
    assert any("out of bounds" in f.message for f in fs)


def test_k001_fires_on_oob_block_index():
    spec = KernelSpec(
        "mut", (2, 4), scalars=(),
        operands=(OperandSpec("kv", (1, 8), lambda b, ik: (b, ik + 1),
                              (2, 4)),))
    assert rules_of(check_kernel_spec(spec)) == {"K001"}


def test_k002_fires_on_masked_but_not_remapped_blocks():
    # identity map + gating predicate: dead blocks still DMA
    spec = KernelSpec(
        "mut", (2, 4), scalars=(POS,),
        operands=(OperandSpec("kv", (1, 8), lambda b, ik, pos: (b, ik),
                              (2, 4)),),
        block_live=lambda b, ik, pos: ik * 8 <= pos[b])
    assert rules_of(check_kernel_spec(spec)) == {"K002"}


def test_k003_fires_on_output_varying_along_reduction():
    spec = KernelSpec(
        "mut", (2, 4), scalars=(),
        operands=(OperandSpec("o", (1, 8), lambda i, k: (i, k), (2, 4),
                              is_output=True),),
        reduction_axes=(1,))
    assert rules_of(check_kernel_spec(spec)) == {"K003"}


def test_guarded_table_records_negative_indices():
    oob = []
    t = _GuardedTable("t", np.arange(8), oob)
    assert t[np.array([-1, 3])][1] == 3  # clipped, evaluation continues
    assert oob and "out of bounds" in oob[0]


def test_shipped_kernel_specs_prove_clean():
    from repro.kernels.block_gemm import gemm_spec
    from repro.kernels.decode_attention import fd_dense_spec, fd_paged_spec
    from repro.kernels.flash_attention import fa_dense_spec, fa_paged_spec

    for spec in (fa_dense_spec(2, 4, 2, 96, 96, 64),
                 fa_paged_spec(2, 4, 2, 32, 64, 16, 4, 9),
                 fd_dense_spec(2, 4, 2, 64, 64, 64, layout="linear"),
                 fd_dense_spec(2, 4, 2, 64, 64, 64, layout="ring"),
                 fd_paged_spec(2, 4, 2, 64, 64, 16, 4, 9),
                 gemm_spec(64, 128, 256),
                 gemm_spec(64, 128, 256, int8=True)):
        assert check_kernel_spec(spec) == [], spec.name


def test_paged_kv_map_oob_without_clamp():
    """Regression: the paged decode kv map *must* clamp — a frozen slot
    (pos == capacity) would otherwise read past the page table."""
    from repro.kernels.decode_attention import fd_paged_spec

    spec = fd_paged_spec(2, 4, 2, 64, 64, 16, 4, 9)
    assert any(op.name == "k" for op in spec.operands)

    def unclamped(b, kh, ik, pos_ref, start_ref, pages_ref):
        return (pages_ref[b, ik], 0, kh, 0)  # no [lo, hi] clamp

    mutated = dataclasses.replace(spec, operands=tuple(
        dataclasses.replace(op, index_map=unclamped)
        if op.name in ("k", "v") else op
        for op in spec.operands))
    assert "K002" in rules_of(check_kernel_spec(mutated))


# ---------------------------------------------------------------------------
# P001: paging invariants
# ---------------------------------------------------------------------------

def test_p001_fires_on_corrupted_refcount():
    pool = PagePool(8)
    pool.alloc()
    pool._rc[2] = 5  # phantom references
    bad = check_invariants(pool)
    assert bad and any("page 2" in m for m in bad)


def test_p001_fires_on_freed_trash_page():
    pool = PagePool(8)
    pool._rc[0] = 0
    pool._free.append(0)
    bad = check_invariants(pool)
    assert sum("trash page" in m for m in bad) == 2


def test_p001_fires_on_table_mismatch():
    pool = PagePool(8)
    p = pool.alloc()
    bad = check_invariants(pool, tables=[[p], [p]])  # two holders, rc == 1
    assert any(f"page {p}" in m for m in bad)


def test_p001_silent_on_healthy_workload():
    pool = PagePool(8)
    radix = RadixCache(2, pool)
    a = [pool.alloc(), pool.alloc()]
    radix.insert([1, 2, 3, 4], a)
    assert check_invariants(pool, radix, [a]) == []
    for p in a:
        pool.decref(p)
    radix.evict(pool.n_pages)
    assert check_invariants(pool, radix, []) == []


# ---------------------------------------------------------------------------
# R001: resilience-branch reachability (mutation-tested like every rule)
# ---------------------------------------------------------------------------

def test_r001_silent_on_healthy_engine():
    from repro.analysis import check_resilience

    report = Report()
    check_resilience(report)
    assert [f for f in report.findings if f.rule == "R001"] == []
    assert "resilience scenarios" in report.checked


def test_r001_fires_when_deadline_expiry_disconnected(monkeypatch):
    """A refactor that stops calling (or no-ops) Scheduler.expire must be
    caught: DEADLINE becomes unreachable and its counter never moves."""
    from repro.analysis import check_resilience
    from repro.serving.engine import Scheduler

    monkeypatch.setattr(Scheduler, "expire",
                        lambda self, now, stats: None)
    report = Report()
    check_resilience(report)
    msgs = [f.message for f in report.findings if f.rule == "R001"]
    assert any("DEADLINE" in m for m in msgs)
    assert any("deadline_expired" in m for m in msgs)


def test_r001_fires_when_cancel_disconnected(monkeypatch):
    from repro.analysis import check_resilience
    from repro.serving.engine import Scheduler

    monkeypatch.setattr(Scheduler, "cancel",
                        lambda self, rid, now, stats: False)
    report = Report()
    check_resilience(report)
    msgs = [f.message for f in report.findings if f.rule == "R001"]
    assert any("CANCELLED" in m for m in msgs)


# ---------------------------------------------------------------------------
# Report plumbing
# ---------------------------------------------------------------------------

def test_report_disable_and_exit_codes(tmp_path):
    r = Report(disabled=["J001"])
    r.add(Finding("J001", "suppressed"))
    r.add(Finding("J002", "kept"))
    assert [f.rule for f in r.findings] == ["J002"]
    assert r.exit_code(strict=True) == 1
    assert Report().exit_code(strict=True) == 0
    p = tmp_path / "report.json"
    r.dump(str(p))
    import json
    data = json.loads(p.read_text())
    assert data["findings"][0]["rule"] == "J002"
    assert set(data["rules"]) == set(RULES)


def test_unknown_rule_rejected_by_cli():
    from repro.analysis.__main__ import main
    with pytest.raises(SystemExit):
        main(["--disable", "XXXX"])


def test_list_rules_cli(capsys):
    from repro.analysis.__main__ import main
    assert main(["--list-rules"]) == 0
    out = capsys.readouterr().out
    for rule in RULES:
        assert rule in out


# ---------------------------------------------------------------------------
# Regression: the genuine findings this checker surfaced
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", ["olmo-1b", "gemma3-4b"])
@pytest.mark.parametrize("quant", ["none", "w8a8"])
def test_logits_reach_sampler_in_f32(name, quant):
    """lm_logits must return f32 even on bf16-compute / w8a8 configs (the
    sampler's argmax ties and top-k tails resolve on full-precision values).
    This was a genuine finding: the head GEMM used to return compute_dtype."""
    from repro.analysis.runner import analysis_config

    cfg = analysis_config(name, "reference", quant)
    assert cfg.compute_dtype == jnp.bfloat16  # the trap this guards against
    params = M.init(cfg, jax.random.PRNGKey(0))
    if quant == "w8a8":
        params = M.quantize_params(cfg, params)
    batch = {"tokens": jnp.zeros((2, 16), jnp.int32)}

    def fwd(p, b):
        hidden, _, _ = M.forward_hidden(cfg, p, b, mode="train")
        return M.lm_logits(cfg, p, hidden)

    out = jax.eval_shape(fwd, params, batch)
    assert out.dtype == jnp.float32


def test_bf16_forward_has_no_unaccumulated_dots():
    """Regression: every bf16 einsum/dot accumulates in f32 (J002-clean)."""
    from repro.analysis.runner import analysis_config

    cfg = analysis_config("gemma3-4b", "reference", "none")
    params = M.init(cfg, jax.random.PRNGKey(0))
    batch = {"tokens": jnp.zeros((2, 16), jnp.int32)}

    def fwd(p, b):
        hidden, _, _ = M.forward_hidden(cfg, p, b, mode="train")
        return M.lm_logits(cfg, p, hidden)

    fs = lint_of(fwd, params, batch)
    assert [f for f in fs if f.rule == "J002"] == []


def test_analysis_smoke_single_config():
    """End-to-end: the checker runs clean on one real config cell and the
    report carries the checked surfaces."""
    from repro.analysis import run_analysis

    report = run_analysis(configs=["olmo-1b"], modes=("reference",),
                          quants=("none",))
    assert report.findings == []
    assert any("entry=decode" in c for c in report.checked)
    assert any("kernel=" in c for c in report.checked)
    assert any("paging" in c for c in report.checked)
