"""Serving resilience: deadlines, cancellation, page-pool preemption with
recompute, per-request fault isolation, bounded-queue rejection, and clean
shutdown (DESIGN.md §10).

Every degraded exit carries a :class:`FinishReason` and increments exactly
one ``ServeStats`` counter; greedy outputs after a recompute-preemption are
bit-identical to the never-preempted run.  Deadline tests drive the engine
clock through :class:`ChaosInjector` skew schedules so they are
deterministic — no sleeps, no wall-clock races.
"""
import jax
import numpy as np
import pytest

from repro.configs import get_config, reduce_config
from repro.models import model as M
from repro.serving import (ChaosInjector, Engine, EngineConfig, FinishReason,
                           bytes_tokenizer_encode)


@pytest.fixture(scope="module")
def olmo():
    cfg = reduce_config(get_config("olmo-1b"))
    params = M.init(cfg, jax.random.PRNGKey(0))
    return cfg, params


@pytest.fixture(scope="module")
def mamba():
    cfg = reduce_config(get_config("mamba2-130m"))
    params = M.init(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _prompts(cfg, texts):
    return [bytes_tokenizer_encode(t, cfg.vocab_size) for t in texts]


def _econ(**kw):
    kw.setdefault("max_len", 96)
    kw.setdefault("page_size", 16)
    kw.setdefault("decode_chunk", 4)
    return EngineConfig(**kw)


def _drain(eng):
    results = []
    while eng.num_queued or eng.num_active:
        results.extend(eng.step())
    results.extend(eng.run())
    return {r.rid: r for r in results}


# ---------------------------------------------------------------------------
# FinishReason: healthy exits
# ---------------------------------------------------------------------------

def test_finish_reason_healthy_exits(olmo):
    cfg, params = olmo
    p = _prompts(cfg, ["healthy"])[0]
    eng = Engine(cfg, params, _econ(max_batch=1))
    r0 = eng.submit(p, max_new=6)
    res = {r.rid: r for r in eng.run()}
    assert res[r0].finish_reason == FinishReason.LENGTH and res[r0].ok
    first = res[r0].generated[0]
    # same prompt with eos_id = its first greedy token -> STOP after 1 token
    eng2 = Engine(cfg, params, _econ(max_batch=1, eos_id=first))
    r1 = eng2.submit(p, max_new=6)
    res2 = {r.rid: r for r in eng2.run()}
    assert res2[r1].finish_reason == FinishReason.STOP and res2[r1].ok
    assert res2[r1].generated == [first]


# ---------------------------------------------------------------------------
# Deadlines (deterministic via injected clock skew)
# ---------------------------------------------------------------------------

def test_deadline_default_override_and_partial_output(olmo):
    """Config-default deadline expires an in-flight request (keeping its
    partial output) and a queued one (empty-handed); a per-submit override
    above the skew survives."""
    cfg, params = olmo
    pa, pb, pc = _prompts(cfg, ["deadline aa", "deadline bb", "deadline cc"])
    chaos = ChaosInjector(schedule={"clock.skew": {3}}, skew_s=1000.0)
    eng = Engine(cfg, params, _econ(max_batch=1, deadline_s=5.0),
                 chaos=chaos)
    ra = eng.submit(pa, max_new=20)                     # config default (5 s)
    rb = eng.submit(pb, max_new=4)                      # queued behind ra
    rc = eng.submit(pc, max_new=4, deadline_s=2000.0)   # outlives the skew
    res = _drain(eng)
    assert res[ra].finish_reason == FinishReason.DEADLINE
    assert len(res[ra].generated) > 0          # in-flight: partial kept
    assert res[rb].finish_reason == FinishReason.DEADLINE
    assert res[rb].generated == []             # queued: never ran
    assert res[rc].finish_reason == FinishReason.LENGTH
    assert eng.stats.deadline_expired == 2
    # the pool reconciles after the expiries
    assert eng.pool.num_free == eng.pool.n_pages - 1

    with pytest.raises(ValueError):
        eng.submit(pa, max_new=4, deadline_s=-1.0)
    with pytest.raises(ValueError):
        EngineConfig(deadline_s=0.0)


# ---------------------------------------------------------------------------
# Cancellation
# ---------------------------------------------------------------------------

def test_cancel_queued_and_inflight(olmo):
    cfg, params = olmo
    pa, pb = _prompts(cfg, ["cancel me aa", "cancel me bb"])
    eng = Engine(cfg, params, _econ(max_batch=1))
    ra = eng.submit(pa, max_new=20)
    rb = eng.submit(pb, max_new=20)
    eng.step()
    eng.step()
    assert eng.cancel(rb)          # still queued: exits empty-handed
    assert eng.cancel(ra)          # in flight: partial output kept
    assert not eng.cancel(999)     # unknown rid
    assert not eng.cancel(ra)      # already retired
    res = _drain(eng)
    assert res[ra].finish_reason == FinishReason.CANCELLED
    assert len(res[ra].generated) > 0
    assert res[rb].finish_reason == FinishReason.CANCELLED
    assert res[rb].generated == []
    assert eng.stats.cancelled == 2
    assert eng.pool.num_free == eng.pool.n_pages - 1


# ---------------------------------------------------------------------------
# Admission: impossible requests still raise, even with preemption on
# ---------------------------------------------------------------------------

def test_submit_time_capacity_errors_with_preemption(olmo):
    """Requests that can *never* run (rows or pages beyond the whole pool)
    raise at submit time in every preemption mode — lazy reservation must
    not admit an impossible request into a preemption livelock."""
    cfg, params = olmo
    for mode in ("off", "recompute"):
        eng = Engine(cfg, params, _econ(max_len=64, max_batch=1, n_pages=3,
                                        preemption=mode))
        with pytest.raises(ValueError, match="max_len"):
            eng.submit(list(range(40)), max_new=32)
        with pytest.raises(ValueError, match="pool capacity"):
            eng.submit(list(range(20)), max_new=20)  # 3 pages > 2 usable


# ---------------------------------------------------------------------------
# Preemption
# ---------------------------------------------------------------------------

def test_preemption_recompute_bit_parity(olmo):
    """Pool exhaustion mid-decode preempts and requeues; greedy outputs are
    bit-identical to a run that never felt pressure."""
    cfg, params = olmo
    rng = np.random.RandomState(0)
    prompts = [rng.randint(1, cfg.vocab_size, 16).tolist() for _ in range(2)]

    big = Engine(cfg, params, _econ(max_len=64, max_batch=2,
                                    prefix_cache=False))
    want, _ = big.generate(prompts, max_new=20)

    small = Engine(cfg, params, _econ(max_len=64, max_batch=2, n_pages=4,
                                      prefix_cache=False,
                                      preemption="recompute"))
    rids = [small.submit(p, max_new=20) for p in prompts]
    res = _drain(small)
    assert small.stats.preempted >= 1
    for rid, p, w in zip(rids, prompts, want):
        assert res[rid].ok
        assert p + res[rid].generated == w   # bit-identical to no-pressure run
    # accounting reconciles after the preempt/recompute churn
    assert small.pool.num_free == small.pool.n_pages - 1


def test_preemption_drop_sheds_lowest_priority(olmo):
    """``preemption="drop"``: the victim retires PREEMPTED with its partial
    output instead of requeueing."""
    cfg, params = olmo
    rng = np.random.RandomState(1)
    prompts = [rng.randint(1, cfg.vocab_size, 16).tolist() for _ in range(2)]
    eng = Engine(cfg, params, _econ(max_len=64, max_batch=2, n_pages=4,
                                    prefix_cache=False, preemption="drop"))
    rids = [eng.submit(p, max_new=20) for p in prompts]
    res = _drain(eng)
    assert eng.stats.preempted == 1
    reasons = sorted(res[r].finish_reason for r in rids)
    assert reasons == [FinishReason.LENGTH, FinishReason.PREEMPTED]
    dropped = next(r for r in rids
                   if res[r].finish_reason == FinishReason.PREEMPTED)
    assert not res[dropped].ok
    assert eng.pool.num_free == eng.pool.n_pages - 1


def test_victim_policy_prefers_fewest_tokens_latest_arrival(olmo):
    """Three decoding slots, one page short: the victim is the slot with
    the fewest generated tokens (ties by latest arrival) — here the last
    request admitted, which yields to the two ahead of it."""
    cfg, params = olmo
    rng = np.random.RandomState(2)
    prompts = [rng.randint(1, cfg.vocab_size, 16).tolist() for _ in range(3)]
    # 5 usable pages vs 3 requests x 2 pages = 6: exactly one short
    eng = Engine(cfg, params, _econ(max_len=32, max_batch=3, n_pages=6,
                                    prefix_cache=False, preemption="drop"))
    rids = [eng.submit(p, max_new=8) for p in prompts]
    res = _drain(eng)
    assert eng.stats.preempted == 1
    assert res[rids[2]].finish_reason == FinishReason.PREEMPTED
    assert res[rids[0]].ok and res[rids[1]].ok


def test_capacity_overrun_degrades_instead_of_raising(olmo):
    """Mirror of test_serving.test_decode_past_capacity_is_explicit_error:
    with preemption enabled the same corrupted accounting degrades to a
    preemption — the engine never raises from check_capacity (ISSUE
    acceptance)."""
    cfg, params = olmo
    eng = Engine(cfg, params, _econ(max_len=32, max_batch=1,
                                    preemption="recompute"))
    rid = eng.submit(_prompts(cfg, ["overrun"])[0], max_new=8)
    eng.step()
    assert eng.num_active == 1
    eng._remaining[0] = 1000  # simulate corrupted length accounting
    res = _drain(eng)         # must not raise
    assert eng.stats.preempted >= 1
    assert res[rid].finish_reason == FinishReason.PREEMPTED
    assert eng.pool.num_free == eng.pool.n_pages - 1


# ---------------------------------------------------------------------------
# Fault isolation
# ---------------------------------------------------------------------------

def test_fault_isolates_poisoned_slot_only(olmo):
    """Injected NaN logits retire exactly the poisoned slot with FAULT; the
    other in-flight request's output is bit-identical to a healthy run."""
    cfg, params = olmo
    pa, pb = _prompts(cfg, ["poison target!", "healthy neighbor"])
    healthy = Engine(cfg, params, _econ(max_batch=2, prefix_cache=False))
    want, _ = healthy.generate([pa, pb], max_new=8)

    chaos = ChaosInjector(schedule={"logits.nan": {2}})
    eng = Engine(cfg, params, _econ(max_batch=2, prefix_cache=False),
                 chaos=chaos)
    ra = eng.submit(pa, max_new=8)   # lowest slot index: the nan target
    rb = eng.submit(pb, max_new=8)
    res = _drain(eng)
    assert res[ra].finish_reason == FinishReason.FAULT and not res[ra].ok
    assert len(res[ra].generated) < 8          # truncated at the bad step
    assert res[rb].finish_reason == FinishReason.LENGTH
    assert pb + res[rb].generated == want[1]   # neighbor unaffected
    assert eng.stats.faults_isolated == 1
    assert eng.pool.num_free == eng.pool.n_pages - 1


# ---------------------------------------------------------------------------
# Shutdown
# ---------------------------------------------------------------------------

def test_close_retires_inflight_and_reconciles(olmo):
    cfg, params = olmo
    pa, pb = _prompts(cfg, ["close one", "close two"])
    eng = Engine(cfg, params, _econ(max_batch=1))
    ra = eng.submit(pa, max_new=20)
    rb = eng.submit(pb, max_new=20)
    eng.step()
    eng.step()
    res = {r.rid: r for r in eng.close()}
    assert res[ra].finish_reason == FinishReason.CANCELLED
    assert len(res[ra].generated) > 0          # partial output preserved
    assert res[rb].finish_reason == FinishReason.CANCELLED
    assert eng.stats.cancelled == 2
    assert eng.pool.num_free == eng.pool.n_pages - 1
    assert eng.close() == []                   # idempotent
    with pytest.raises(RuntimeError, match="closed"):
        eng.submit(pa, max_new=4)
    with pytest.raises(RuntimeError, match="closed"):
        eng.step()


def test_context_manager_closes_with_radix_state(olmo):
    """Exit-through-``with`` reconciles even with radix-shared pages and
    preemption enabled mid-flight."""
    cfg, params = olmo
    rng = np.random.RandomState(3)
    prefix = rng.randint(1, cfg.vocab_size, 32).tolist()
    prompts = [prefix + rng.randint(1, cfg.vocab_size, 4).tolist()
               for _ in range(3)]
    with Engine(cfg, params, _econ(max_batch=2, preemption="recompute")) \
            as eng:
        eng.generate(prompts[:2], max_new=4)   # publishes prefix pages
        eng.submit(prompts[2], max_new=20)
        eng.step()
        pool = eng.pool
    assert eng._closed
    assert pool.num_free == pool.n_pages - 1


# ---------------------------------------------------------------------------
# Counters: exactly once per event, chunked and unchunked
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("chunk_tokens", [None, 8])
def test_counters_increment_exactly_once(olmo, chunk_tokens):
    """One composed scenario producing exactly one REJECTED, CANCELLED,
    FAULT and DEADLINE each — every counter moves exactly once, across both
    the chunked and whole-suffix prefill paths."""
    cfg, params = olmo
    rng = np.random.RandomState(4)
    mk = lambda: rng.randint(1, cfg.vocab_size, 20).tolist()
    chaos = ChaosInjector(schedule={"logits.nan": {2}, "clock.skew": {6}},
                          skew_s=1000.0)
    eng = Engine(cfg, params,
                 _econ(max_batch=1, max_queue=2, prefix_cache=False,
                       chunk_tokens=chunk_tokens),
                 chaos=chaos)
    ra = eng.submit(mk(), max_new=6)                    # will FAULT (tick 2)
    rb = eng.submit(mk(), max_new=6)                    # cancelled in queue
    rc = eng.submit(mk(), max_new=6)                    # queue full: REJECTED
    assert eng.cancel(rb)
    rd = eng.submit(mk(), max_new=30, deadline_s=5.0)   # expires at tick 6
    res = _drain(eng)
    assert res[ra].finish_reason == FinishReason.FAULT
    assert res[rb].finish_reason == FinishReason.CANCELLED
    assert res[rc].finish_reason == FinishReason.REJECTED
    assert res[rc].retry_after_s > 0
    assert res[rd].finish_reason == FinishReason.DEADLINE
    s = eng.stats
    assert (s.rejected, s.cancelled, s.faults_isolated,
            s.deadline_expired, s.preempted) == (1, 1, 1, 1, 0)
    assert len(res) == 4
    assert eng.pool.num_free == eng.pool.n_pages - 1


# ---------------------------------------------------------------------------
# Non-decomposable (whole-prompt prefill) models
# ---------------------------------------------------------------------------

def test_whole_prefill_models_cancel_and_deadline(mamba):
    """SSM prefill is not chunkable; deadlines and cancellation must still
    work through the inline whole-prompt admission path."""
    cfg, params = mamba
    pa, pb = _prompts(cfg, ["state space aa", "state space bb"])
    chaos = ChaosInjector(schedule={"clock.skew": {4}}, skew_s=1000.0)
    eng = Engine(cfg, params, _econ(max_batch=1), chaos=chaos)
    ra = eng.submit(pa, max_new=30)
    rb = eng.submit(pb, max_new=30, deadline_s=5.0)
    eng.step()
    assert eng.cancel(ra)          # in flight (decoding after whole prefill)
    res = _drain(eng)
    assert res[ra].finish_reason == FinishReason.CANCELLED
    assert len(res[ra].generated) > 0
    assert res[rb].finish_reason == FinishReason.DEADLINE
    assert eng.stats.cancelled == 1 and eng.stats.deadline_expired == 1
    assert eng.pool.num_free == eng.pool.n_pages - 1
