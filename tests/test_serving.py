"""Paged continuous-batching engine: page allocation/recycling, radix prefix
reuse (hit accounting, COW divergence, capacity wins at fixed memory),
paged-vs-unpaged greedy parity (linear and sliding-window/ring-equivalent
configs, reference and interpret kernel modes), admission control, the
legacy-kwargs deprecation shim, and mid-flight arrivals."""
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduce_config
from repro.models import model as M
from repro.serving import (Engine, EngineConfig, FinishReason,
                           bytes_tokenizer_encode)


@pytest.fixture(scope="module")
def olmo():
    cfg = reduce_config(get_config("olmo-1b"))
    params = M.init(cfg, jax.random.PRNGKey(0))
    return cfg, params


@pytest.fixture(scope="module")
def gemma():
    """Local/global interleave with a sliding window — under paging the
    window layers express validity via ``start`` instead of a ring, so this
    is the ring-equivalent configuration."""
    cfg = reduce_config(get_config("gemma3-4b"))
    params = M.init(cfg, jax.random.PRNGKey(0))
    return cfg, params


@pytest.fixture(scope="module")
def edge():
    cfg = reduce_config(get_config("cgra-edge"))
    params = M.init(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _prompts(cfg, texts):
    return [bytes_tokenizer_encode(t, cfg.vocab_size) for t in texts]


def _econ(**kw):
    kw.setdefault("max_len", 96)
    kw.setdefault("page_size", 16)
    kw.setdefault("decode_chunk", 4)
    return EngineConfig(**kw)


def reference_greedy(cfg, params, prompt, max_new):
    """Unpaged exact-length loop: one prefill with the linear cache
    pre-padded to plen + max_new rows, then per-token ``decode_step`` —
    the oracle every paged engine output must match bit for bit."""
    plen = len(prompt)
    logits, caches = M.prefill(cfg, params,
                               {"tokens": jnp.asarray([prompt], jnp.int32)},
                               cache_len=plen + max_new)
    cur = int(jnp.argmax(logits[0, -1, : cfg.vocab_size]))
    out = [cur]
    for step in range(max_new - 1):
        logits, caches = M.decode_step(cfg, params, caches,
                                       jnp.asarray([[cur]], jnp.int32),
                                       jnp.int32(plen + step))
        cur = int(jnp.argmax(logits[0, -1, : cfg.vocab_size]))
        out.append(cur)
    return out


# ---------------------------------------------------------------------------
# scheduling / page lifecycle
# ---------------------------------------------------------------------------

def test_page_recycling_and_reuse(olmo):
    """5 requests through 2 batch rows: pages recycle through the pool and
    every request completes with its full token budget; after the drain the
    pool holds only radix-cached pages."""
    cfg, params = olmo
    eng = Engine(cfg, params, _econ(max_batch=2))
    prompts = _prompts(cfg, ["a", "bb", "ccc", "dddd", "eeeee"])
    rids = [eng.submit(p, max_new=5) for p in prompts]
    results = {r.rid: r for r in eng.run()}
    assert sorted(results) == sorted(rids)
    for rid, p in zip(rids, prompts):
        assert len(results[rid].generated) == 5
        assert results[rid].prompt == p
    assert eng.num_active == 0 and eng.num_queued == 0
    assert eng.stats.prefills == 5
    assert eng.stats.peak_active <= 2
    # every page either returned to the free list or is held by the tree
    for pid in range(1, eng.pool.n_pages):
        assert eng.pool.refcount(pid) in (0, 1)


def test_matches_unbatched_reference_greedy(olmo):
    """Paged scan decode == unpaged exact-length loop, token for token."""
    cfg, params = olmo
    eng = Engine(cfg, params, _econ(max_batch=3))
    prompts = _prompts(cfg, ["hello world", "x", "the quick brown fox"])
    out, _ = eng.generate(prompts, max_new=6)
    for p, seq in zip(prompts, out):
        assert seq[len(p):] == reference_greedy(cfg, params, p, 6)


def test_window_config_matches_reference(gemma):
    """Ring-equivalent config: sliding-window layers on the paged cache
    (validity via start) decode identically to the unbatched reference path
    with its ring caches, for prompts shorter AND longer than the window."""
    cfg, params = gemma
    assert cfg.window_size and cfg.local_global_pattern
    eng = Engine(cfg, params, _econ(max_len=128, max_batch=2))
    short = _prompts(cfg, ["tiny"])[0]                      # < window
    long = _prompts(cfg, ["w" * (cfg.window_size + 9)])[0]  # > window
    out, _ = eng.generate([short, long], max_new=6)
    for p, seq in zip([short, long], out):
        assert seq[len(p):] == reference_greedy(cfg, params, p, 6)


def test_greedy_independent_of_batch_composition(olmo):
    cfg, params = olmo
    target = _prompts(cfg, ["the target request"])[0]
    mates_a = _prompts(cfg, ["one", "completely different"])
    mates_b = _prompts(cfg, ["nine nine nine nine nine nine"])

    def gen_with(mates, max_batch):
        eng = Engine(cfg, params, _econ(max_batch=max_batch))
        out, _ = eng.generate([target] + mates, max_new=6)
        return out[0]

    solo = gen_with([], 1)
    assert gen_with(mates_a, 3) == solo
    assert gen_with(mates_b, 2) == solo


def test_admission_control(olmo):
    cfg, params = olmo
    eng = Engine(cfg, params, _econ(max_len=64, max_batch=1, max_queue=2))
    with pytest.raises(ValueError):  # can never fit: 40 + 32 > max_len
        eng.submit(list(range(40)), max_new=32)
    with pytest.raises(ValueError):
        eng.submit([], max_new=4)
    eng.submit([1, 2, 3], max_new=4)
    eng.submit([1, 2, 3], max_new=4)
    # queue bound -> backpressure: never a raise or a silent drop, the
    # request finishes immediately as REJECTED with a retry hint
    rej = eng.submit([1, 2, 3], max_new=4)
    res = {r.rid: r for r in eng.run()}
    assert len(res) == 3
    assert res[rej].finish_reason == FinishReason.REJECTED
    assert not res[rej].ok and res[rej].retry_after_s > 0
    assert eng.stats.rejected == 1
    assert sum(r.ok for r in res.values()) == 2


def test_admission_rejects_requests_larger_than_pool(olmo):
    """A request whose page need exceeds the whole pool can never run."""
    cfg, params = olmo
    eng = Engine(cfg, params, _econ(max_len=96, max_batch=1, n_pages=3))
    with pytest.raises(ValueError, match="pages"):
        eng.submit(list(range(40)), max_new=8)  # needs 3 pages, pool has 2


def test_head_of_line_blocking_until_pages_free(olmo):
    """When the pool cannot serve the head request, admission waits for
    retirements instead of failing — and the request then completes."""
    cfg, params = olmo
    # 5 usable pages: an in-flight 3-page request leaves 2 free; the queued
    # 3-page request must wait for the first to retire.
    eng = Engine(cfg, params, _econ(max_len=96, max_batch=2, n_pages=6,
                                    prefix_cache=False))
    a = eng.submit(list(range(40)), max_new=8)      # 3 pages
    b = eng.submit(list(range(40, 80)), max_new=8)  # 3 pages: must wait
    results = eng.step()
    assert eng.num_active == 1 and eng.num_queued == 1
    while eng.num_active or eng.num_queued:
        results.extend(eng.step())
    assert sorted(r.rid for r in results) == [a, b]
    assert all(len(r.generated) == 8 for r in results)


def test_mid_flight_arrival(olmo):
    """Requests submitted while others decode land in freed batch rows and
    finish with results identical to a solo run (continuous batching)."""
    cfg, params = olmo
    eng = Engine(cfg, params, _econ(max_batch=2, decode_chunk=2))
    first = _prompts(cfg, ["alpha", "beta"])
    late = _prompts(cfg, ["late arrival"])[0]
    for p in first:
        eng.submit(p, max_new=8)
    results = list(eng.step())  # decode in flight
    eng.submit(late, max_new=4)
    while eng.num_active or eng.num_queued:
        results.extend(eng.step())
    by_rid = {r.rid: r for r in results}
    assert len(by_rid) == 3
    solo = Engine(cfg, params, _econ(max_batch=2, decode_chunk=2))
    solo_out, _ = solo.generate([late], max_new=4)
    assert by_rid[2].tokens == solo_out[0]


def test_eos_stops_early(olmo):
    cfg, params = olmo
    probe = Engine(cfg, params, _econ(max_batch=1))
    p = _prompts(cfg, ["stop early"])[0]
    out, _ = probe.generate([p], max_new=8)
    gen = out[0][len(p):]
    eos = gen[2]  # pretend the 3rd generated token is the stop token
    eng = Engine(cfg, params, _econ(max_batch=1, eos_id=eos))
    res = {r.rid: r for r in (eng.submit(p, max_new=8), eng.run())[1]}
    assert res[0].generated == gen[: gen.index(eos) + 1]  # cut at first eos
    assert res[0].generated[-1] == eos


def test_per_request_temperature_and_seed(olmo):
    cfg, params = olmo
    eng = Engine(cfg, params, _econ(max_batch=2))
    p = _prompts(cfg, ["sample me"])[0]
    r1 = eng.submit(p, max_new=10, temperature=1.0, seed=1)
    r2 = eng.submit(p, max_new=10, temperature=1.0, seed=2)
    res = {r.rid: r for r in eng.run()}
    assert res[r1].generated != res[r2].generated


def test_decode_past_capacity_is_explicit_error(olmo):
    """A slot whose length accounting would overrun its reserved pages must
    surface an explicit error, never silently write the trash page."""
    cfg, params = olmo
    eng = Engine(cfg, params, _econ(max_len=32, max_batch=1))
    eng.submit(_prompts(cfg, ["overrun"])[0], max_new=8)  # legal: 15 <= 32
    eng.step()
    assert eng.num_active == 1
    eng._remaining[0] = 1000  # simulate corrupted length accounting
    with pytest.raises(RuntimeError, match="overruns KV capacity"):
        while eng.num_active:
            eng.step()


# ---------------------------------------------------------------------------
# prefix reuse
# ---------------------------------------------------------------------------

def test_prefix_hit_shares_pages_and_outputs_match(olmo):
    """Two requests sharing a 40-token prefix (2.5 pages of 16): the second
    admission incref-shares the 2 full pages, takes the third (where the
    prompts diverge at row 8) as a copy-on-write share, and still emits
    exactly the solo-run tokens."""
    cfg, params = olmo
    rng = np.random.RandomState(0)
    prefix = rng.randint(3, cfg.vocab_size, 40).tolist()
    p1 = prefix + [1] * 8  # 48 tokens: exactly 3 full pages
    p2 = prefix + [2] * 6  # diverges from p1 at row 8 of page 3
    eng = Engine(cfg, params, _econ(max_batch=2))
    out, stats = eng.generate([p1, p2], max_new=6)
    # p2 matched 2 full pages (32) + 8 COW rows of p1's cached third page
    assert stats.prefix_hit_tokens == 40
    assert stats.prefix_lookup_tokens == len(p1) + len(p2)
    assert eng.prefix_hit_rate == pytest.approx(40 / 94)
    for p, seq in zip([p1, p2], out):
        assert seq[len(p):] == reference_greedy(cfg, params, p, 6)


def test_matched_prefix_pages_survive_eviction_pressure(olmo):
    """Regression: the pages a radix lookup matches must be pinned before
    eviction runs.  Unpinned, a tree-only matched page (refcount 1) was a
    legitimate LRU victim for the very evict() making room for the same
    request — incref then hit a freed page (crash), or worse the page was
    handed to another sequence.  Now the blocked request simply waits."""
    cfg, params = olmo
    eng = Engine(cfg, params, _econ(max_len=96, max_batch=2, n_pages=7))
    rng = np.random.RandomState(7)
    prefix = rng.randint(3, cfg.vocab_size, 32).tolist()
    a = eng.submit(prefix, max_new=16)                # 3 pages
    results = eng.run()                               # retires; 2 prompt
    assert eng.pool.num_free == 4                     # pages stay cached
    filler = rng.randint(3, cfg.vocab_size, 16).tolist()
    c = eng.submit(filler, max_new=32)                # 3 pages: pool drained
    results.extend(eng.step())
    assert eng.num_active == 1 and eng.pool.num_free == 1
    pb = prefix + rng.randint(3, cfg.vocab_size, 16).tolist()
    b = eng.submit(pb, max_new=16)                    # 4 pages, 2 matched
    results.extend(eng.step())
    assert eng.num_queued == 1                        # blocked, not crashed
    assert eng.pool.refcount(1) == 1                  # matched prefix pages
    assert eng.pool.refcount(2) == 1                  # still radix-held
    while eng.num_active or eng.num_queued:
        results.extend(eng.step())
    by_rid = {r.rid: r for r in results}
    assert sorted(by_rid) == sorted([a, b, c])
    assert by_rid[b].generated == reference_greedy(cfg, params, pb, 16)
    for pid in range(1, eng.pool.n_pages):            # seq refs all released
        assert eng.pool.refcount(pid) in (0, 1)


def test_blocked_admission_does_not_evict_prefix_cache(olmo):
    """Regression: when the head-of-line request stays blocked even after
    eviction could run, admission must not evict at all — cached prefix
    pages were being thrown away for a request that remained queued."""
    cfg, params = olmo
    eng = Engine(cfg, params, _econ(max_len=96, max_batch=2, n_pages=7))
    rng = np.random.RandomState(8)
    prefix = rng.randint(3, cfg.vocab_size, 32).tolist()
    eng.submit(prefix, max_new=16)                    # 3 pages
    results = eng.run()                               # tree keeps 2 pages
    eng.submit(rng.randint(3, cfg.vocab_size, 16).tolist(), max_new=32)
    results.extend(eng.step())                        # live: 3 pages
    assert eng.pool.num_free == 1
    pb = rng.randint(3, cfg.vocab_size, 32).tolist()  # no shared prefix
    b = eng.submit(pb, max_new=32)                    # 4 pages, 0 matched
    results.extend(eng.step())
    assert eng.num_queued == 1                        # blocked: 1 free + 2
    assert eng.pool.refcount(1) == 1                  # evictable < 4 needed,
    assert eng.pool.refcount(2) == 1                  # so nothing evicted
    while eng.num_active or eng.num_queued:           # retirement frees 2;
        results.extend(eng.step())                    # now eviction helps
    by_rid = {r.rid: r for r in results}
    assert by_rid[b].generated == reference_greedy(cfg, params, pb, 32)


def test_prefill_compile_cache_is_bounded(olmo):
    """The suffix-prefill jit cache LRU-evicts beyond max_prefill_variants
    (unbounded growth under varied prompt lengths), and recompiling an
    evicted variant stays correct."""
    cfg, params = olmo
    eng = Engine(cfg, params, _econ(max_batch=1))
    eng.max_prefill_variants = 2
    prompts = _prompts(cfg, ["a", "bb", "ccc", "dddd", "eee"])
    out, _ = eng.generate(prompts, max_new=4)
    assert len(eng._prefill_fns) <= 2
    for p, seq in zip(prompts, out):
        assert seq[len(p):] == reference_greedy(cfg, params, p, 4)


def test_prefix_cache_auto_disabled_for_ssm():
    """SSM prefill is not prefix-decomposable: the engine must refuse to
    radix-share even when the config asks for it."""
    cfg = reduce_config(get_config("mamba2-130m"))
    params = M.init(cfg, jax.random.PRNGKey(0))
    eng = Engine(cfg, params, _econ(max_batch=2, prefix_cache=True))
    assert eng.radix is None
    p = _prompts(cfg, ["state space"])[0]
    out, _ = eng.generate([p], max_new=4)
    assert len(out[0]) == len(p) + 4


def test_paged_prefix_reuse_beats_fixed_slot_at_equal_memory(edge):
    """ISSUE acceptance: 8 requests sharing a 512-token prefix.  At an
    equal KV row budget the paged+radix engine decodes all 8 concurrently
    where fixed per-slot allocation fits a single sequence — and every
    output stays bit-identical to the unpaged exact-length loop."""
    cfg, params = edge
    ps, n_req, prefix_len, suffix_len, max_new = 64, 8, 512, 8, 8
    rng = np.random.RandomState(3)
    prefix = rng.randint(1, cfg.vocab_size, prefix_len).tolist()
    prompts = [prefix + rng.randint(1, cfg.vocab_size, suffix_len).tolist()
               for _ in range(n_req)]
    rows = prefix_len + suffix_len + max_new  # 528 rows per request
    max_len = -(-rows // ps) * ps             # 576
    # budget: the shared prefix once + one private tail page per request
    n_pages = 1 + prefix_len // ps + n_req * (
        -(-rows // ps) - prefix_len // ps)
    econ = EngineConfig(max_len=max_len, max_batch=n_req, page_size=ps,
                        n_pages=n_pages, decode_chunk=4)
    eng = Engine(cfg, params, econ)
    out, stats = eng.generate(prompts, max_new=max_new)
    # all 8 admitted at once: the prefix pages are shared, not copied ...
    assert stats.peak_active == n_req
    # ... which is strictly more than per-slot allocation at equal memory
    fixed_slot_concurrency = econ.cache_spec().max_rows // max_len
    assert stats.peak_active > fixed_slot_concurrency
    assert fixed_slot_concurrency == 1
    assert eng.prefix_hit_rate > 0.5  # requests 2..8 each hit 512/520
    # paged-vs-unpaged greedy outputs: bit-identical
    for p, seq in zip(prompts, out):
        assert seq[len(p):] == reference_greedy(cfg, params, p, max_new)


# ---------------------------------------------------------------------------
# EngineConfig surface / legacy shim
# ---------------------------------------------------------------------------

def test_legacy_kwargs_shim(olmo):
    """The pre-paging Engine signature still works, under DeprecationWarning:
    max_slots -> max_batch, prefill_bucket ignored, capacity preserved."""
    cfg, params = olmo
    with pytest.warns(DeprecationWarning):
        eng = Engine(cfg, params, max_len=96, max_slots=2, prefill_bucket=16,
                     decode_chunk=4)
    assert eng.max_batch == 2 and eng.decode_chunk == 4
    assert eng.cache_spec.max_rows >= 2 * 96  # legacy row capacity kept
    with pytest.warns(DeprecationWarning):  # legacy positional max_len
        eng2 = Engine(cfg, params, 96)
    assert eng2.max_len >= 96
    p = _prompts(cfg, ["legacy caller"])[0]
    out, _ = eng.generate([p], max_new=5)
    assert len(out[0]) == len(p) + 5


def test_engine_config_and_legacy_kwargs_are_exclusive(olmo):
    cfg, params = olmo
    with pytest.raises(TypeError):
        Engine(cfg, params, EngineConfig(), max_slots=2)
    with pytest.raises(TypeError):
        Engine(cfg, params, bogus_knob=1)


def test_engine_config_defaults_no_warning(olmo):
    cfg, params = olmo
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        eng = Engine(cfg, params)
        Engine(cfg, params, EngineConfig(max_len=128, page_size=32))
    assert eng.max_len == 512 and eng.page_size == 64
    assert eng.pool.n_pages == 8 * (512 // 64) + 1


def test_engine_config_validation():
    with pytest.raises(ValueError):
        EngineConfig(page_size=12)  # not a multiple of 8
    with pytest.raises(ValueError):
        EngineConfig(n_pages=1)
    assert EngineConfig(max_len=100, page_size=32).max_len == 128  # rounded


# ---------------------------------------------------------------------------
# kernel_mode / quant through the paged engine
# ---------------------------------------------------------------------------

def test_engine_w8a8_serves_full_budget(olmo):
    """quant="w8a8": weights quantized once at engine construction; prefill
    and scan-decode run through the packed int8 GEMM path end to end."""
    from repro.core.quant import QTensor
    cfg, params = olmo
    eng = Engine(cfg, params, _econ(max_batch=2, quant="w8a8"))
    assert eng.cfg.quant == "w8a8"
    assert isinstance(eng.params["lm_head"], QTensor)
    prompts = _prompts(cfg, ["int8 one", "int8 two", "int8 three"])
    out, _ = eng.generate(prompts, max_new=6)
    for p, seq in zip(prompts, out):
        assert len(seq) == len(p) + 6
        assert all(0 <= t < cfg.vocab_size for t in seq)


def test_paged_interpret_matches_unpaged_on_cgra_edge(edge):
    """ISSUE acceptance: paged-vs-unpaged greedy parity on the edge config
    in interpret mode — both sides run the exact Pallas kernel math, the
    engine side through the paged flash-decode's page-table index map, with
    a shared prefix exercising radix reuse + partial-page COW."""
    cfg, params = edge
    cfg_i = cfg.with_(kernel_mode="interpret")
    common = "shared edge prefix tokens: "  # 27 bytes: 1 full 16-page + COW
    prompts = _prompts(cfg, [common + "request one", common + "request two",
                             "cold prompt"])
    eng = Engine(cfg_i, params, _econ(max_len=64, max_batch=2))
    out, _ = eng.generate(prompts, max_new=6)
    assert eng.stats.prefix_hit_tokens > 16  # page share + COW rows hit
    for p, seq in zip(prompts, out):
        assert seq[len(p):] == reference_greedy(cfg_i, params, p, 6)


def test_engine_kernel_mode_override(olmo):
    """kernel_mode is threaded from the config into prefill + decode; the
    reference override must reproduce the default engine token-for-token."""
    cfg, params = olmo
    a = Engine(cfg, params, _econ(max_batch=1))
    b = Engine(cfg, params, _econ(max_batch=1, kernel_mode="reference"))
    p = _prompts(cfg, ["kernel mode"])[0]
    assert a.generate([p], max_new=5)[0] == b.generate([p], max_new=5)[0]
