"""Continuous-batching engine: slot eviction/reuse, ring-cache correctness vs
the unbatched reference decode path, batch-composition invariance, admission
control, and mid-flight arrivals."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduce_config
from repro.models import model as M
from repro.serving.engine import Engine, bytes_tokenizer_encode, grow_cache


@pytest.fixture(scope="module")
def olmo():
    cfg = reduce_config(get_config("olmo-1b"))
    params = M.init(cfg, jax.random.PRNGKey(0))
    return cfg, params


@pytest.fixture(scope="module")
def gemma():
    """Local/global interleave with a sliding window -> ring KV caches."""
    cfg = reduce_config(get_config("gemma3-4b"))
    params = M.init(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _prompts(cfg, texts):
    return [bytes_tokenizer_encode(t, cfg.vocab_size) for t in texts]


def reference_greedy(cfg, params, prompt, plen, max_new):
    """Seed-style unbatched path: single prefill + per-token Python loop over
    ``decode_step`` with a grow_cache'd linear cache.  Passes the left-pad
    ``start`` offset like the engine, so pad rows stay dead on both paths."""
    start = plen - len(prompt)
    toks = np.zeros((1, plen), np.int32)
    toks[0, start:] = prompt
    logits, caches = M.prefill(cfg, params, {"tokens": jnp.asarray(toks)},
                               start=jnp.int32(start))
    caches = grow_cache(cfg, caches, plen + max_new)
    cur = int(jnp.argmax(logits[0, -1, : cfg.vocab_size]))
    out = [cur]
    for step in range(max_new - 1):
        logits, caches = M.decode_step(cfg, params, caches,
                                       jnp.asarray([[cur]], jnp.int32),
                                       jnp.int32(plen + step),
                                       start=jnp.int32(start))
        cur = int(jnp.argmax(logits[0, -1, : cfg.vocab_size]))
        out.append(cur)
    return out


def test_slot_eviction_and_reuse(olmo):
    """5 requests through 2 slots: every slot is recycled at least once and
    every request still completes with its full token budget."""
    cfg, params = olmo
    eng = Engine(cfg, params, max_len=96, max_slots=2, prefill_bucket=16,
                 decode_chunk=4)
    prompts = _prompts(cfg, ["a", "bb", "ccc", "dddd", "eeeee"])
    rids = [eng.submit(p, max_new=5) for p in prompts]
    results = {r.rid: r for r in eng.run()}
    assert sorted(results) == sorted(rids)
    for rid, p in zip(rids, prompts):
        assert len(results[rid].generated) == 5
        assert results[rid].prompt == p
    assert eng.num_active == 0 and eng.num_queued == 0
    assert eng.stats.prefills == 5  # each admission prefilled a freed slot


def test_matches_unbatched_reference_greedy(olmo):
    """Scan decode + slot cache == seed-style unbatched loop, token for token."""
    cfg, params = olmo
    eng = Engine(cfg, params, max_len=96, max_slots=3, prefill_bucket=16,
                 decode_chunk=4)
    prompts = _prompts(cfg, ["hello world", "x", "the quick brown fox"])
    out, _ = eng.generate(prompts, max_new=6)
    for p, seq in zip(prompts, out):
        ref = reference_greedy(cfg, params, p, eng.padded_len(len(p)), 6)
        assert seq[len(p):] == ref


def test_ring_cache_matches_reference(gemma):
    """Sliding-window ring caches: prompts shorter AND longer than the window
    decode identically to the unbatched reference path."""
    cfg, params = gemma
    assert cfg.window_size and cfg.local_global_pattern  # ring layers present
    eng = Engine(cfg, params, max_len=128, max_slots=2, prefill_bucket=16,
                 decode_chunk=4)
    short = _prompts(cfg, ["tiny"])[0]                      # < window
    long = _prompts(cfg, ["w" * (cfg.window_size + 9)])[0]  # > window: rolled ring
    out, _ = eng.generate([short, long], max_new=6)
    for p, seq in zip([short, long], out):
        ref = reference_greedy(cfg, params, p, eng.padded_len(len(p)), 6)
        assert seq[len(p):] == ref


def test_greedy_independent_of_batch_composition(olmo):
    cfg, params = olmo
    target = _prompts(cfg, ["the target request"])[0]
    mates_a = _prompts(cfg, ["one", "completely different"])
    mates_b = _prompts(cfg, ["nine nine nine nine nine nine"])

    def gen_with(mates, max_slots):
        eng = Engine(cfg, params, max_len=96, max_slots=max_slots,
                     prefill_bucket=16, decode_chunk=4)
        out, _ = eng.generate([target] + mates, max_new=6)
        return out[0]

    solo = gen_with([], 1)
    assert gen_with(mates_a, 3) == solo
    assert gen_with(mates_b, 2) == solo


def test_admission_control(olmo):
    cfg, params = olmo
    eng = Engine(cfg, params, max_len=64, max_slots=1, prefill_bucket=16,
                 max_queue=2)
    with pytest.raises(ValueError):  # can never fit: 64-row cache
        eng.submit(list(range(40)), max_new=32)
    with pytest.raises(ValueError):
        eng.submit([], max_new=4)
    eng.submit([1, 2, 3], max_new=4)
    eng.submit([1, 2, 3], max_new=4)
    with pytest.raises(RuntimeError):  # queue bound -> backpressure
        eng.submit([1, 2, 3], max_new=4)
    assert len(eng.run()) == 2


def test_mid_flight_arrival(olmo):
    """Requests submitted while others decode land in freed slots and finish
    with results identical to a solo run (continuous batching)."""
    cfg, params = olmo
    eng = Engine(cfg, params, max_len=96, max_slots=2, prefill_bucket=16,
                 decode_chunk=2)
    first = _prompts(cfg, ["alpha", "beta"])
    late = _prompts(cfg, ["late arrival"])[0]
    for p in first:
        eng.submit(p, max_new=8)
    results = list(eng.step())  # decode in flight
    eng.submit(late, max_new=4)
    while eng.num_active or eng.num_queued:
        results.extend(eng.step())
    by_rid = {r.rid: r for r in results}
    assert len(by_rid) == 3
    solo = Engine(cfg, params, max_len=96, max_slots=2, prefill_bucket=16,
                  decode_chunk=2)
    solo_out, _ = solo.generate([late], max_new=4)
    assert by_rid[2].tokens == solo_out[0]


def test_eos_stops_early(olmo):
    cfg, params = olmo
    probe = Engine(cfg, params, max_len=96, max_slots=1, prefill_bucket=16,
                   decode_chunk=4)
    p = _prompts(cfg, ["stop early"])[0]
    out, _ = probe.generate([p], max_new=8)
    gen = out[0][len(p):]
    eos = gen[2]  # pretend the 3rd generated token is the stop token
    eng = Engine(cfg, params, max_len=96, max_slots=1, prefill_bucket=16,
                 decode_chunk=4, eos_id=eos)
    res = {r.rid: r for r in (eng.submit(p, max_new=8), eng.run())[1]}
    assert res[0].generated == gen[: gen.index(eos) + 1]  # cut at first eos
    assert res[0].generated[-1] == eos


def test_per_request_temperature_and_seed(olmo):
    cfg, params = olmo
    eng = Engine(cfg, params, max_len=96, max_slots=2, prefill_bucket=16)
    p = _prompts(cfg, ["sample me"])[0]
    r1 = eng.submit(p, max_new=10, temperature=1.0, seed=1)
    r2 = eng.submit(p, max_new=10, temperature=1.0, seed=2)
    res = {r.rid: r for r in eng.run()}
    assert res[r1].generated != res[r2].generated


def test_decode_past_capacity_is_explicit_error(olmo):
    """A slot whose length accounting would overrun its KV capacity must
    surface an explicit error, never silently drop/overwrite cache rows
    (global layers used to clamp the write index onto the last row)."""
    cfg, params = olmo
    eng = Engine(cfg, params, max_len=32, max_slots=1, prefill_bucket=16,
                 decode_chunk=4)
    eng.submit(_prompts(cfg, ["overrun"])[0], max_new=8)  # legal: 16+8 <= 32
    eng.step()
    assert eng.num_active == 1
    eng._remaining[0] = 1000  # simulate corrupted length accounting
    with pytest.raises(RuntimeError, match="overruns KV capacity"):
        while eng.num_active:
            eng.step()


def test_engine_w8a8_serves_full_budget(olmo):
    """quant="w8a8": weights quantized once at engine construction; prefill
    and scan-decode run through the packed int8 GEMM path end to end."""
    from repro.core.quant import QTensor
    cfg, params = olmo
    eng = Engine(cfg, params, max_len=96, max_slots=2, prefill_bucket=16,
                 decode_chunk=4, quant="w8a8")
    assert eng.cfg.quant == "w8a8"
    assert isinstance(eng.params["lm_head"], QTensor)
    prompts = _prompts(cfg, ["int8 one", "int8 two", "int8 three"])
    out, _ = eng.generate(prompts, max_new=6)
    for p, seq in zip(prompts, out):
        assert len(seq) == len(p) + 6
        assert all(0 <= t < cfg.vocab_size for t in seq)


def test_outputs_invariant_to_prefill_bucket(olmo):
    """Left-pad KV pollution regression: the bucket pad rows must be fully
    dead (masked in prefill attention, excluded from decode validity, RoPE
    offset by ``start``), so a request's greedy output is bit-identical
    whether its prompt is padded to its own length, 32 or 64 rows."""
    cfg, params = olmo
    prompt = _prompts(cfg, ["the target request"])[0]  # len 18: ragged
    outs = []
    for bucket in (len(prompt), 32, 64):
        eng = Engine(cfg, params, max_len=128, max_slots=2,
                     prefill_bucket=bucket, decode_chunk=4)
        out, _ = eng.generate([prompt], max_new=8)
        outs.append(out[0][len(prompt):])
    assert outs[0] == outs[1] == outs[2], outs


def test_ring_outputs_invariant_to_prefill_bucket(gemma):
    """Same invariance through sliding-window ring caches (pad rows can
    survive the prefill ring roll when the prompt is shorter than the
    window — decode validity must drop them by absolute row)."""
    cfg, params = gemma
    prompt = _prompts(cfg, ["ring pads"])[0]
    outs = []
    for bucket in (16, 48):
        eng = Engine(cfg, params, max_len=128, max_slots=2,
                     prefill_bucket=bucket, decode_chunk=4)
        out, _ = eng.generate([prompt], max_new=6)
        outs.append(out[0][len(prompt):])
    assert outs[0] == outs[1], outs


def test_engine_interpret_decode_matches_reference(olmo):
    """The decode hot path obeys kernel_mode: the interpret engine (flash
    decode through the Pallas interpreter) reproduces the reference engine
    token for token, including recycled slots with distinct pad offsets."""
    cfg, params = olmo
    prompts = _prompts(cfg, ["kernel", "decode path", "third one longer"])
    outs = []
    for mode in (None, "interpret"):
        eng = Engine(cfg, params, max_len=96, max_slots=2, prefill_bucket=16,
                     decode_chunk=4, kernel_mode=mode)
        out, _ = eng.generate(prompts, max_new=6)
        outs.append(out)
    assert outs[0] == outs[1]


def test_engine_kernel_mode_override(olmo):
    """kernel_mode is threaded from the engine into prefill + decode; the
    reference override must reproduce the default engine token-for-token."""
    cfg, params = olmo
    a = Engine(cfg, params, max_len=96, max_slots=1, prefill_bucket=16)
    b = Engine(cfg, params, max_len=96, max_slots=1, prefill_bucket=16,
               kernel_mode="reference")
    p = _prompts(cfg, ["kernel mode"])[0]
    assert a.generate([p], max_new=5)[0] == b.generate([p], max_new=5)[0]
