"""Fault tolerance: checkpoint/restart, failure injection, straggler
monitor, elastic reshard-on-load."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.manager import CheckpointManager
from repro.configs import get_config, reduce_config
from repro.data.pipeline import SyntheticLM
from repro.runtime.ft import FailureInjector, StragglerMonitor, TrainRunner
from repro.training import AdamWConfig, init_state, make_train_step


@pytest.fixture()
def tiny():
    cfg = reduce_config(get_config("olmo-1b"))
    opt = AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=50, clip_norm=1.0)
    step = make_train_step(cfg, opt)
    data = SyntheticLM(cfg, batch=2, seq=32)
    state = init_state(cfg, opt, jax.random.PRNGKey(0))
    return cfg, step, data, state


def test_checkpoint_roundtrip(tmp_path, tiny):
    cfg, step, data, state = tiny
    mgr = CheckpointManager(str(tmp_path), keep_n=2, async_save=False)
    mgr.save(3, state)
    restored = mgr.restore(3, state)
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_keep_n_gc(tmp_path, tiny):
    cfg, step, data, state = tiny
    mgr = CheckpointManager(str(tmp_path), keep_n=2, async_save=False)
    for s in (1, 2, 3, 4):
        mgr.save(s, {"x": jnp.ones(3) * s})
    assert mgr.all_steps() == [3, 4]


def test_restart_resumes_exact_stream(tmp_path, tiny):
    """Run 8 steps straight vs 8 steps with a crash at step 5: identical."""
    cfg, step, data, state = tiny
    batch_fn = lambda s: data.batch_at(s)

    m1 = CheckpointManager(str(tmp_path / "a"), async_save=False)
    r1 = TrainRunner(step, batch_fn, m1, ckpt_every=2)
    s1, rep1 = r1.run(state, 8)

    m2 = CheckpointManager(str(tmp_path / "b"), async_save=False)
    inj = FailureInjector(fail_at={5})
    r2 = TrainRunner(step, batch_fn, m2, ckpt_every=2, injector=inj)
    s2, rep2 = r2.run(state, 8)

    assert rep2.restarts == 1
    assert rep2.steps_run > 8  # re-ran steps 4..5 after restart
    for a, b in zip(jax.tree.leaves(s1.params), jax.tree.leaves(s2.params)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=1e-6)


def test_straggler_monitor_flags_slow_steps():
    mon = StragglerMonitor(threshold=2.0, warmup=2)
    for s in range(6):
        assert not mon.observe(s, 0.10)
    assert mon.observe(6, 0.50)
    assert mon.flagged and mon.flagged[0][0] == 6


def test_elastic_reshard_on_load(tmp_path, tiny):
    """Save, then restore onto a different (simulated) DP degree: the
    checkpoint stores logical arrays, so any target sharding works."""
    cfg, step, data, state = tiny
    mgr = CheckpointManager(str(tmp_path), async_save=False)
    mgr.save(1, state)
    # target: same structure, explicit single-device sharding (the reshard
    # path; on a pod this is NamedSharding on the new mesh)
    sharding = jax.sharding.SingleDeviceSharding(jax.devices()[0])
    restored = mgr.restore(1, state, shardings=sharding)
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_nonfinite_loss_triggers_restart(tmp_path, tiny):
    cfg, step, data, state = tiny
    calls = {"n": 0}

    def poisoned_step(st, batch):
        calls["n"] += 1
        st2, m = step(st, batch)
        if calls["n"] == 4:
            m = dict(m, loss=jnp.float32(np.nan))
        return st2, m

    mgr = CheckpointManager(str(tmp_path), async_save=False)
    runner = TrainRunner(poisoned_step, lambda s: data.batch_at(s), mgr,
                         ckpt_every=2)
    s2, rep = runner.run(state, 6)
    assert rep.restarts == 1
    assert rep.final_step == 6


def test_async_save_matches_sync(tmp_path, tiny):
    cfg, step, data, state = tiny
    m_async = CheckpointManager(str(tmp_path / "as"), async_save=True)
    m_sync = CheckpointManager(str(tmp_path / "sy"), async_save=False)
    m_async.save(7, state)
    m_sync.save(7, state)
    m_async.wait()
    a = m_async.restore(7, state)
    b = m_sync.restore(7, state)
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
