"""MoE dispatch correctness against a dense every-expert reference, drop
behaviour, and SPMD notes.

(The expert-sharded shard_map path is flag-gated off on CPU: XLA's CPU
AllReducePromotion pass check-fails cloning the copy-combiner all-reduce its
partitioner emits for auto-axis contractions inside manual regions.  Minimal
repro: shard_map{scatter-set + einsum over an FSDP-sharded dim} under
jax.checkpoint.  TPU backends are unaffected; cfg.moe_shard_map enables it.)
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _prop import given, settings, st  # hypothesis, or deterministic fallback

from repro.configs import get_config, reduce_config
from repro.models import layers as L
from repro.models.params import init_params


def setup(E=4, k=2, D=16, F=8, cf=4.0, groups=2, seed=0):
    cfg = reduce_config(get_config("qwen3-moe-30b-a3b")).with_(
        num_experts=E, experts_per_token=k, d_model=D, moe_d_ff=F,
        capacity_factor=cf, num_moe_groups=groups)
    p = init_params(L.moe_specs(cfg), jax.random.PRNGKey(seed), jnp.float32)
    return cfg, p


def dense_reference(cfg, p, x):
    xt = x.reshape(-1, cfg.d_model)
    probs = jax.nn.softmax(xt @ p["router"], -1)
    topw, topi = jax.lax.top_k(probs, cfg.experts_per_token)
    topw = topw / topw.sum(-1, keepdims=True)
    ref = np.zeros_like(np.asarray(xt))
    for t in range(xt.shape[0]):
        for j in range(cfg.experts_per_token):
            e = int(topi[t, j])
            v = xt[t]
            y = (jax.nn.silu(v @ p["w_gate"][e]) * (v @ p["w_up"][e])) @ p["w_down"][e]
            ref[t] += float(topw[t, j]) * np.asarray(y)
    return ref.reshape(x.shape)


@pytest.mark.parametrize("groups", [1, 2, 4])
def test_moe_matches_dense_reference(groups):
    cfg, p = setup(groups=groups)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model), jnp.float32)
    out, aux = L.moe_forward(cfg, p, x)
    np.testing.assert_allclose(np.asarray(out), dense_reference(cfg, p, x),
                               atol=1e-4)
    assert float(aux) > 0.9  # balanced aux loss ~= 1 for near-uniform routing


def test_moe_capacity_drops_tokens():
    """With tiny capacity some tokens must be dropped (output -> partial)."""
    cfg, p = setup(cf=0.1)
    cfg = cfg.with_(capacity_factor=0.01)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 64, cfg.d_model), jnp.float32)
    out, _ = L.moe_forward(cfg, p, x)
    dense = dense_reference(cfg, p, x)
    # dropped tokens produce strictly smaller-norm outputs; ensure no NaNs and
    # that at least one token was dropped (outputs differ)
    assert np.isfinite(np.asarray(out)).all()
    assert np.abs(np.asarray(out) - dense).max() > 1e-4


def test_moe_gradients_flow_to_all_param_kinds():
    cfg, p = setup()
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model), jnp.float32)

    def loss(p):
        out, aux = L.moe_forward(cfg, p, x)
        return (out ** 2).sum() + 0.01 * aux

    g = jax.grad(loss)(p)
    for k, v in g.items():
        assert np.isfinite(np.asarray(v, np.float32)).all(), k
        assert float(jnp.abs(v.astype(jnp.float32)).sum()) > 0, f"no grad: {k}"


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_prop_moe_first_choice_never_dropped_at_cf1(seed):
    """With capacity_factor >= k and one group, priority slots cover all
    first choices: the top-1 expert contribution is always present."""
    cfg, p = setup(E=4, k=1, cf=4.0, groups=1, seed=seed % 3)
    x = jax.random.normal(jax.random.PRNGKey(seed), (1, 8, cfg.d_model), jnp.float32)
    out, _ = L.moe_forward(cfg, p, x)
    np.testing.assert_allclose(np.asarray(out), dense_reference(cfg, p, x),
                               atol=1e-4)
