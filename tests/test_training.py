"""Optimizer, schedules, gradient accumulation, 8-bit moments, data pipeline."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduce_config
from repro.data.pipeline import SyntheticLM
from repro.training import (AdamWConfig, init_state, make_train_step, schedule)
from repro.training.optimizer import adamw_update, global_norm, init_moments


def test_schedule_warmup_cosine():
    opt = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=110, min_lr_ratio=0.1)
    assert float(schedule(opt, jnp.int32(0))) == 0.0
    assert abs(float(schedule(opt, jnp.int32(10))) - 1.0) < 1e-6
    assert abs(float(schedule(opt, jnp.int32(110))) - 0.1) < 1e-6
    mid = float(schedule(opt, jnp.int32(60)))
    assert 0.1 < mid < 1.0


def test_adamw_descends_quadratic():
    opt = AdamWConfig(lr=0.1, warmup_steps=0, total_steps=1000,
                      weight_decay=0.0, clip_norm=1e9)
    params = {"w": jnp.asarray([3.0, -2.0])}
    mu, nu = init_moments(params, opt)
    step = jnp.int32(0)
    for i in range(200):
        g = {"w": 2 * params["w"]}
        params, mu, nu, _ = adamw_update(opt, params, g, mu, nu, step + i)
    assert float(jnp.abs(params["w"]).max()) < 0.1


def test_grad_clipping_bounds_update():
    opt = AdamWConfig(lr=1.0, warmup_steps=0, clip_norm=1e-3, weight_decay=0.0)
    params = {"w": jnp.zeros(4)}
    mu, nu = init_moments(params, opt)
    g = {"w": jnp.full(4, 1e6)}
    _, mu2, _, m = adamw_update(opt, params, g, mu, nu, jnp.int32(0))
    assert float(m["grad_norm"]) > 1e5  # reported raw norm
    assert float(jnp.abs(jax.tree.leaves(mu2)[0]).max()) < 1.0  # clipped moment


@pytest.mark.parametrize("moments", ["f32", "bf16", "int8"])
def test_moments_dtype_variants_step(moments):
    cfg = reduce_config(get_config("olmo-1b"))
    opt = AdamWConfig(moments_dtype=moments, warmup_steps=0, total_steps=10)
    state = init_state(cfg, opt, jax.random.PRNGKey(0))
    step = make_train_step(cfg, opt)
    data = SyntheticLM(cfg, batch=2, seq=32)
    s2, m = step(state, data.batch_at(0))
    assert np.isfinite(float(m["loss"]))
    # params actually moved
    d = sum(float(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32)).sum())
            for a, b in zip(jax.tree.leaves(state.params), jax.tree.leaves(s2.params)))
    assert d > 0


def test_grad_accumulation_matches_full_batch():
    cfg = reduce_config(get_config("olmo-1b"))
    opt = AdamWConfig(warmup_steps=0, total_steps=10, clip_norm=1e9,
                      weight_decay=0.0)
    data = SyntheticLM(cfg, batch=4, seq=32)
    batch = data.batch_at(0)
    state = init_state(cfg, opt, jax.random.PRNGKey(0))
    s_full, m_full = make_train_step(cfg, opt)(state, batch)
    s_acc, m_acc = make_train_step(cfg, opt, accum_steps=2)(state, batch)
    np.testing.assert_allclose(float(m_full["loss"]), float(m_acc["loss"]),
                               rtol=1e-4)
    for a, b in zip(jax.tree.leaves(s_full.params), jax.tree.leaves(s_acc.params)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=2e-5)


def test_data_pipeline_deterministic_and_shifted():
    cfg = reduce_config(get_config("olmo-1b"))
    d1 = SyntheticLM(cfg, batch=2, seq=16, seed=7)
    d2 = SyntheticLM(cfg, batch=2, seq=16, seed=7)
    b1, b2 = d1.batch_at(5), d2.batch_at(5)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    np.testing.assert_array_equal(b1["tokens"][:, 1:], b1["labels"][:, :-1])
    b3 = d1.batch_at(6)
    assert not np.array_equal(b1["tokens"], b3["tokens"])


def test_global_norm():
    t = {"a": jnp.asarray([3.0]), "b": jnp.asarray([4.0])}
    assert abs(float(global_norm(t)) - 5.0) < 1e-6
