"""Per-kernel validation: Pallas (interpret=True) vs pure-jnp oracles,
swept over shapes and dtypes, plus hypothesis property tests and the
kernel-vs-model parity suite (flash_attention against ``layers.attend``)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _prop import given, settings, st  # hypothesis, or deterministic fallback

from repro.core.gemm import cgra_gemm, cgra_gemm_w8a8
from repro.core.quant import dequantize, quantize
from repro.kernels import ref
from repro.kernels.block_gemm import block_gemm, block_gemm_int8
from repro.kernels.flash_attention import flash_attention
from repro.kernels.ops import attention, cgra_matmul
from repro.models.layers import attend

RNG = np.random.RandomState(0)


# ---------------------------------------------------------------------------
# block GEMM
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("shape", [(128, 128, 128), (256, 128, 384),
                                   (200, 150, 330), (64, 300, 72), (8, 8, 8)])
@pytest.mark.parametrize("dtype", [np.float32, jnp.bfloat16])
def test_block_gemm_matches_oracle(shape, dtype):
    M, K, N = shape
    a = jnp.asarray(RNG.randn(M, K), dtype)
    b = jnp.asarray(RNG.randn(K, N), dtype)
    out = block_gemm(a, b, block_shape=(128, 128, 128), interpret=True)
    want = ref.block_gemm_ref(a, b)
    atol = 1e-3 if dtype == np.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), atol=atol, rtol=1e-2)


@pytest.mark.parametrize("block", [(128, 128, 128), (256, 128, 128)])
def test_block_gemm_block_shapes(block):
    a = jnp.asarray(RNG.randn(256, 256), jnp.float32)
    b = jnp.asarray(RNG.randn(256, 256), jnp.float32)
    out = block_gemm(a, b, block_shape=block, interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(a @ b), atol=1e-3)


@pytest.mark.parametrize("shape", [(128, 128, 128), (200, 300, 170)])
def test_block_gemm_int8(shape):
    M, K, N = shape
    a = RNG.randn(M, K).astype(np.float32)
    b = RNG.randn(K, N).astype(np.float32)
    aq = quantize(jnp.asarray(a), axis=0)
    bq = quantize(jnp.asarray(b), axis=-1)
    out = block_gemm_int8(aq.q, bq.q, aq.scale, bq.scale.reshape(1, -1),
                          block_shape=(128, 128, 128), interpret=True)
    want = ref.block_gemm_int8_ref(aq.q, bq.q, aq.scale, bq.scale.reshape(1, -1))
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=1e-3)
    # quantization itself is accurate to ~1%
    rel = np.abs(np.asarray(out) - a @ b) / (np.abs(a @ b) + 1.0)
    assert np.median(rel) < 0.05


def test_block_gemm_custom_vjp():
    a = jnp.asarray(RNG.randn(128, 128), jnp.float32)
    b = jnp.asarray(RNG.randn(128, 128), jnp.float32)
    ga, gb = jax.grad(lambda x, y: cgra_matmul(x, y, "interpret").sum(), (0, 1))(a, b)
    ga_r, gb_r = jax.grad(lambda x, y: (x @ y).sum(), (0, 1))(a, b)
    np.testing.assert_allclose(np.asarray(ga), np.asarray(ga_r), atol=1e-3)
    np.testing.assert_allclose(np.asarray(gb), np.asarray(gb_r), atol=1e-3)


def test_cgra_gemm_batched():
    x = jnp.asarray(RNG.randn(4, 32, 64), jnp.float32)
    w = jnp.asarray(RNG.randn(64, 48), jnp.float32)
    np.testing.assert_allclose(np.asarray(cgra_gemm(x, w)),
                               np.asarray(x @ w), atol=1e-4)


def test_w8a8_interpret_vs_reference():
    x = jnp.asarray(RNG.randn(100, 160), jnp.float32)
    w = quantize(jnp.asarray(RNG.randn(160, 90), jnp.float32), axis=-1)
    a = cgra_gemm_w8a8(x, w, mode="interpret")
    b = cgra_gemm_w8a8(x, w, mode="reference")
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-3)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,H,K,S,D,causal,window", [
    (2, 4, 4, 256, 64, True, 0),
    (1, 8, 2, 256, 64, True, 0),   # GQA 4:1
    (2, 4, 2, 256, 64, True, 64),  # sliding window
    (1, 4, 4, 128, 64, False, 0),  # bidirectional (encoder)
    (1, 4, 1, 128, 32, True, 0),   # MQA
])
def test_flash_attention_matches_oracle(B, H, K, S, D, causal, window):
    q = jnp.asarray(RNG.randn(B, H, S, D) * 0.3, jnp.float32)
    k = jnp.asarray(RNG.randn(B, K, S, D) * 0.3, jnp.float32)
    v = jnp.asarray(RNG.randn(B, K, S, D) * 0.3, jnp.float32)
    out = flash_attention(q, k, v, causal=causal, window=window,
                          bq=64, bk=64, interpret=True)
    G = H // K
    want = ref.flash_attention_ref(q, jnp.repeat(k, G, 1), jnp.repeat(v, G, 1),
                                   causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=2e-3, rtol=1e-2)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_dtypes(dtype):
    q = jnp.asarray(RNG.randn(1, 2, 128, 64) * 0.3, dtype)
    k = jnp.asarray(RNG.randn(1, 2, 128, 64) * 0.3, dtype)
    v = jnp.asarray(RNG.randn(1, 2, 128, 64) * 0.3, dtype)
    out = flash_attention(q, k, v, causal=True, bq=64, bk=64, interpret=True)
    want = ref.flash_attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), atol=2e-2)


@pytest.mark.parametrize("Sq,Sk", [(100, 100), (77, 77), (130, 130),
                                   (200, 200), (96, 160)])
def test_flash_attention_ragged_shapes(Sq, Sk):
    """Arbitrary (non-block-multiple) lengths: padded up to the block grid,
    padded keys masked, output sliced back — no assertion errors."""
    q = jnp.asarray(RNG.randn(1, 4, Sq, 32) * 0.3, jnp.float32)
    k = jnp.asarray(RNG.randn(1, 2, Sk, 32) * 0.3, jnp.float32)
    v = jnp.asarray(RNG.randn(1, 2, Sk, 32) * 0.3, jnp.float32)
    out = flash_attention(q, k, v, causal=True, bq=64, bk=64, interpret=True)
    want = ref.flash_attention_ref(q, jnp.repeat(k, 2, 1), jnp.repeat(v, 2, 1),
                                   causal=True)
    assert out.shape == (1, 4, Sq, 32)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=2e-3, rtol=1e-2)


def test_flash_attention_fully_masked_rows_are_zero():
    """Causal with Sq > Sk: the first Sq-Sk-? queries precede every key, so
    their rows are fully masked and must come out exactly zero (the old
    kernel returned mean(V): exp(s - m) == 1 when m never left -inf)."""
    Sq, Sk = 64, 32
    q = jnp.asarray(RNG.randn(1, 2, Sq, 32) * 0.3, jnp.float32)
    k = jnp.asarray(RNG.randn(1, 2, Sk, 32) * 0.3, jnp.float32)
    v = jnp.asarray(RNG.randn(1, 2, Sk, 32) + 5.0, jnp.float32)  # mean(V) != 0
    out = flash_attention(q, k, v, causal=True, bq=32, bk=32, interpret=True)
    # query row i attends keys kpos <= i + (Sk - Sq); rows i < Sq-Sk see none
    masked = np.asarray(out[:, :, : Sq - Sk])
    assert np.all(masked == 0.0), np.abs(masked).max()
    want = ref.flash_attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=2e-3)


@pytest.mark.parametrize("softcap", [20.0, 50.0])
def test_flash_attention_softcap(softcap):
    q = jnp.asarray(RNG.randn(1, 4, 128, 32), jnp.float32)
    k = jnp.asarray(RNG.randn(1, 4, 128, 32), jnp.float32)
    v = jnp.asarray(RNG.randn(1, 4, 128, 32), jnp.float32)
    out = flash_attention(q, k, v, causal=True, softcap=softcap,
                          bq=64, bk=64, interpret=True)
    want = ref.flash_attention_ref(q, k, v, causal=True, softcap=softcap)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=2e-3, rtol=1e-2)


# ---------------------------------------------------------------------------
# kernel vs model parity: ops.attention (interpret) against layers.attend —
# the jnp core the model actually validates against — across the
# causal/window/GQA/softcap/ragged grid, in the model's [B,S,H,d] layout.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("S,H,K,window,softcap", [
    (128, 4, 4, 0, 0.0),
    (128, 8, 2, 0, 0.0),    # GQA 4:1
    (96, 4, 2, 32, 0.0),    # sliding window, ragged
    (100, 4, 4, 0, 30.0),   # softcap (Gemma-3 style), ragged
    (130, 6, 2, 48, 20.0),  # everything at once
])
def test_attention_matches_attend(S, H, K, window, softcap):
    d = 16
    q = jnp.asarray(RNG.randn(2, S, H, d) * 0.3, jnp.float32)
    k = jnp.asarray(RNG.randn(2, S, K, d) * 0.3, jnp.float32)
    v = jnp.asarray(RNG.randn(2, S, K, d) * 0.3, jnp.float32)
    pos = jnp.arange(S)
    want = attend(q, k, v, pos, pos, causal=True, window=window,
                  softcap=softcap)
    got = attention(q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
                    v.transpose(0, 2, 1, 3), causal=True, window=window,
                    softcap=softcap, mode="interpret", bq=64, bk=64
                    ).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-3, rtol=1e-2)


@pytest.mark.parametrize("Sq,chunk", [(52, 16), (64, 24), (100, 32), (33, 32)])
def test_attend_chunked_ragged_matches_unchunked(Sq, chunk):
    """Query chunking must honor ``attn_chunk`` even when Sq % chunk != 0
    (the old path silently fell back to unchunked): the tail chunk is padded
    and sliced, numerically identical to the unchunked oracle."""
    H, K, d = 4, 2, 16
    q = jnp.asarray(RNG.randn(2, Sq, H, d) * 0.3, jnp.float32)
    k = jnp.asarray(RNG.randn(2, Sq, K, d) * 0.3, jnp.float32)
    v = jnp.asarray(RNG.randn(2, Sq, K, d) * 0.3, jnp.float32)
    pos = jnp.arange(Sq)
    want = attend(q, k, v, pos, pos, causal=True, window=24)
    got = attend(q, k, v, pos, pos, causal=True, window=24, chunk=chunk)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-6, rtol=1e-6)


@pytest.mark.parametrize("chunk", [0, 16, 24])
def test_attend_per_row_positions(chunk):
    """[B, Sq] per-row positions (continuous-batching left-pad offsets) work
    in both the unchunked and chunked paths (the old chunked path crashed
    reshaping [B, Sq] as [Sq]), and negative (pad) key positions are masked:
    each row must match a solo run of its unpadded tail."""
    B, S, H, K, d = 2, 48, 4, 2, 16
    starts = [0, 13]
    q = jnp.asarray(RNG.randn(B, S, H, d) * 0.3, jnp.float32)
    k = jnp.asarray(RNG.randn(B, S, K, d) * 0.3, jnp.float32)
    v = jnp.asarray(RNG.randn(B, S, K, d) * 0.3, jnp.float32)
    pos = jnp.stack([jnp.arange(S) - s for s in starts])  # [B, S]
    got = attend(q, k, v, pos, pos, causal=True, chunk=chunk)
    for b, s in enumerate(starts):
        solo = attend(q[b:b + 1, s:], k[b:b + 1, s:], v[b:b + 1, s:],
                      jnp.arange(S - s), jnp.arange(S - s), causal=True)
        np.testing.assert_allclose(np.asarray(got[b:b + 1, s:]),
                                   np.asarray(solo), atol=1e-5, rtol=1e-5,
                                   err_msg=f"b={b} chunk={chunk}")
        # pad query rows attend nothing -> exact zeros (flash contract)
        assert np.all(np.asarray(got[b, :s]) == 0.0)


def test_flash_attention_suffix_alignment():
    """Sq < Sk (suffix prefill over a cached prefix): the causal rule aligns
    the last query with the last key, so query row i attends keys
    ``kpos <= i + (Sk - Sq)`` — the kernel must match the oracle and a
    padded-query solo run of the full sequence."""
    B, H, Sq, Sk, d = 2, 4, 32, 96, 32
    q = jnp.asarray(RNG.randn(B, H, Sq, d) * 0.3, jnp.float32)
    k = jnp.asarray(RNG.randn(B, H, Sk, d) * 0.3, jnp.float32)
    v = jnp.asarray(RNG.randn(B, H, Sk, d) * 0.3, jnp.float32)
    got = flash_attention(q, k, v, causal=True, bq=32, bk=32, interpret=True)
    want = ref.flash_attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-3, rtol=1e-2)
    # equivalently: the last Sq rows of a full-length self-attention whose
    # first Sk - Sq queries are the prefix itself
    qf = jnp.concatenate([k[:, :, : Sk - Sq], q], axis=2)
    full = flash_attention(qf, k, v, causal=True, bq=32, bk=32,
                           interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(full[:, :, -Sq:]),
                               atol=2e-3, rtol=1e-2)


def test_w8a8_within_quant_error_of_fp32():
    """cgra_gemm_w8a8 (interpret) vs the fp32 GEMM: median relative error
    bounded by int8 quantization noise."""
    x = jnp.asarray(RNG.randn(96, 160), jnp.float32)
    w = jnp.asarray(RNG.randn(160, 90), jnp.float32)
    wq = quantize(w, axis=-1)
    got = np.asarray(cgra_gemm_w8a8(x, wq, mode="interpret"))
    want = np.asarray(x @ w)
    rel = np.abs(got - want) / (np.abs(want) + 1.0)
    assert np.median(rel) < 0.02, np.median(rel)
    assert np.max(rel) < 0.5, np.max(rel)


# ---------------------------------------------------------------------------
# property tests (hypothesis)
# ---------------------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(m=st.integers(1, 64), k=st.integers(1, 64), n=st.integers(1, 64))
def test_prop_block_gemm_any_shape(m, k, n):
    """Padding handles every shape; result == jnp matmul."""
    a = jnp.asarray(RNG.randn(m, k), jnp.float32)
    b = jnp.asarray(RNG.randn(k, n), jnp.float32)
    out = block_gemm(a, b, block_shape=(32, 32, 32), interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(a @ b), atol=1e-3)


@settings(max_examples=25, deadline=None)
@given(rows=st.integers(1, 40), cols=st.integers(1, 40))
def test_prop_quant_roundtrip_bound(rows, cols):
    """|dequant(quant(x)) - x| <= amax/127 per channel (symmetric int8)."""
    x = jnp.asarray(RNG.randn(rows, cols), jnp.float32)
    qt = quantize(x, axis=-1)
    back = dequantize(qt)
    bound = np.asarray(jnp.max(jnp.abs(x), axis=0, keepdims=True)) / 127.0
    assert np.all(np.abs(np.asarray(back - x)) <= bound + 1e-6)


@settings(max_examples=10, deadline=None)
@given(s=st.sampled_from([64, 128, 192]), w=st.sampled_from([0, 32, 64]))
def test_prop_flash_attention_window(s, w):
    q = jnp.asarray(RNG.randn(1, 2, s, 32) * 0.3, jnp.float32)
    k = jnp.asarray(RNG.randn(1, 2, s, 32) * 0.3, jnp.float32)
    v = jnp.asarray(RNG.randn(1, 2, s, 32) * 0.3, jnp.float32)
    out = flash_attention(q, k, v, causal=True, window=w, bq=32, bk=32,
                          interpret=True)
    want = ref.flash_attention_ref(q, k, v, causal=True, window=w)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=2e-3)
