"""Free compiled executables between test modules.

The whole tier-1 suite runs in one process and XLA:CPU never unloads
jitted code, so compiled executables accumulate across modules until a
late compilation crashes the JIT (observed as a deterministic segfault in
``backend_compile`` once enough modules have run).  Collecting dead
engines/functions and clearing JAX's caches at each module boundary keeps
the live-code footprint bounded by the largest module instead of the
whole suite."""
import gc

import jax
import pytest


@pytest.fixture(autouse=True, scope="module")
def _clear_jax_caches_between_modules():
    yield
    gc.collect()
    jax.clear_caches()
