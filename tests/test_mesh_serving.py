"""Mesh-sharded serving vs single-device references.

The engine on a ``1xM`` model-parallel mesh must be *bit-identical* to the
single-device engine for greedy decode on dense configs (argmax is robust
to the float-reduction reorderings sharding introduces), and
logits-close (<= 1e-4) for the MoE expert-parallel path.  Multi-device
parity runs in a subprocess with
``--xla_force_host_platform_device_count=8`` so the main pytest process
keeps its single-device view (same isolation rule as test_torus.py);
mesh-spec parsing and device-count validation run in-process.
"""
import os
import subprocess
import sys
import textwrap

import pytest

import jax

from repro.serving import EngineConfig, MeshSpec

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    assert jax.device_count() == 8, jax.device_count()

    from repro.configs import get_config, reduce_config
    from repro.models import model as M
    from repro.serving import Engine, EngineConfig, MeshSpec

    cfg = reduce_config(get_config("cgra-edge"))
    params = M.init(cfg, jax.random.PRNGKey(0))
    prompts = [[(7 * i + j) % cfg.vocab_size for j in range(5 + 3 * i)]
               for i in range(4)]
    kw = dict(max_batch=4, max_len=128, page_size=16)

    # dense greedy parity: bit-identical tokens across mesh widths and
    # prefill styles (whole-suffix and chunked)
    for chunk in (None, 8):
        base, _ = Engine(cfg, params,
                         EngineConfig(chunk_tokens=chunk, **kw)
                         ).generate(prompts, max_new=12)
        for m in (2, 8):
            eng = Engine(cfg, params,
                         EngineConfig(mesh=MeshSpec(1, m),
                                      chunk_tokens=chunk, **kw))
            out, _ = eng.generate(prompts, max_new=12)
            assert out == base, f"mesh 1x{m} chunk={chunk} diverged"
    print("DENSE-PARITY-OK")

    # radix prefix reuse under mesh: second batch shares the first's
    # prefix pages, decodes must stay identical to an engine without reuse
    shared = prompts[0] * 7          # 35 tokens: spans two full 16-row pages
    family = [shared + [t] for t in (1, 2, 3)]
    meng = Engine(cfg, params, EngineConfig(mesh=MeshSpec(1, 2), **kw))
    got, _ = meng.generate(family, max_new=8)
    assert meng.prefix_hit_rate > 0, "radix cache never hit under mesh"
    cold = Engine(cfg, params, EngineConfig(mesh=MeshSpec(1, 2),
                                            prefix_cache=False, **kw))
    want, _ = cold.generate(family, max_new=8)
    assert got == want, "prefix reuse changed tokens under mesh"
    print("RADIX-OK")

    # mid-stream chunked prefill: submit while decodes are in flight so
    # mixed steps interleave prefill chunks with decode under the mesh
    seng = Engine(cfg, params, EngineConfig(mesh=MeshSpec(1, 2),
                                            chunk_tokens=8, **kw))
    seng.submit(prompts[0], 16, 0.0, seed=0)
    results = seng.step()
    seng.submit(prompts[1], 16, 0.0, seed=1)   # joins mid-decode
    while seng.num_queued or seng.num_active:
        results.extend(seng.step())
    ref = Engine(cfg, params, EngineConfig(chunk_tokens=8, **kw))
    ref.submit(prompts[0], 16, 0.0, seed=0)
    rres = ref.step()
    ref.submit(prompts[1], 16, 0.0, seed=1)
    while ref.num_queued or ref.num_active:
        rres.extend(ref.step())
    tok = lambda rs: sorted((r.rid, tuple(r.generated)) for r in rs)
    assert tok(results) == tok(rres), "mid-stream prefill diverged"
    print("MIDSTREAM-OK")

    # resilience counters under mesh: the same composed scenario as
    # tests/test_resilience.py (one REJECTED / CANCELLED / FAULT /
    # DEADLINE each) must move every ServeStats counter exactly once
    # through the sharded executables
    from repro.serving import ChaosInjector, FinishReason
    chaos = ChaosInjector(schedule={"logits.nan": {2}, "clock.skew": {6}},
                          skew_s=1000.0)
    reng = Engine(cfg, params,
                  EngineConfig(mesh=MeshSpec(1, 2), max_batch=1, max_len=128,
                               page_size=16, decode_chunk=4, max_queue=2,
                               prefix_cache=False),
                  chaos=chaos)
    mk = lambda i: [(11 * i + j) % cfg.vocab_size for j in range(20)]
    ra = reng.submit(mk(1), 6)                      # FAULT at tick 2
    rb = reng.submit(mk(2), 6)                      # cancelled in queue
    rc = reng.submit(mk(3), 6)                      # queue full: REJECTED
    assert reng.cancel(rb)
    rd = reng.submit(mk(4), 30, deadline_s=5.0)     # expires at tick 6
    rres = []
    while reng.num_queued or reng.num_active:
        rres.extend(reng.step())
    rres.extend(reng.run())
    rmap = {r.rid: r.finish_reason for r in rres}
    assert rmap == {ra: FinishReason.FAULT, rb: FinishReason.CANCELLED,
                    rc: FinishReason.REJECTED, rd: FinishReason.DEADLINE}, rmap
    s = reng.stats
    assert (s.rejected, s.cancelled, s.faults_isolated, s.deadline_expired,
            s.preempted) == (1, 1, 1, 1, 0)
    assert reng.pool.num_free == reng.pool.n_pages - 1
    print("RESILIENCE-OK")

    # MoE expert-parallel decode: tokens match greedy single-device and
    # prefill logits stay within 1e-4
    mcfg = reduce_config(get_config("qwen3-moe-30b-a3b"))
    mparams = M.init(mcfg, jax.random.PRNGKey(1))
    mp = [[(3 * i + j) % mcfg.vocab_size for j in range(6 + 2 * i)]
          for i in range(3)]
    mref, _ = Engine(mcfg, mparams, EngineConfig(**kw)).generate(mp, max_new=8)
    meng = Engine(mcfg, mparams, EngineConfig(mesh=MeshSpec(1, 2), **kw))
    assert meng.cfg.moe_shard_map, "expert-parallel routing not enabled"
    mout, _ = meng.generate(mp, max_new=8)
    assert mout == mref, "MoE greedy tokens diverged under mesh"

    from repro.launch.sharding import activation_mesh
    toks = jnp.asarray(np.array([mp[0]]), jnp.int32)
    lg_ref = M.prefill(mcfg, mparams, {"tokens": toks})[0]
    mesh = MeshSpec(1, 2).build()
    scfg = mcfg.with_(moe_shard_map=True)
    sp = M.shard_params(scfg, mparams, mesh)
    with activation_mesh(mesh):
        lg = jax.jit(lambda p, t: M.prefill(scfg, p, {"tokens": t})[0])(
            sp, toks)
    d = float(jnp.max(jnp.abs(lg - lg_ref)))
    assert d <= 1e-4, f"MoE prefill logits diverged: {d}"
    print("MOE-PARITY-OK")
""")


@pytest.mark.slow
def test_mesh_serving_parity_subprocess():
    env = dict(os.environ, PYTHONPATH=SRC)
    env.pop("XLA_FLAGS", None)
    res = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                         capture_output=True, text=True, timeout=600)
    out = res.stdout
    for sentinel in ("DENSE-PARITY-OK", "RADIX-OK", "MIDSTREAM-OK",
                     "RESILIENCE-OK", "MOE-PARITY-OK"):
        assert sentinel in out, out + res.stderr


# -- in-process: spec parsing and mesh construction -------------------------

def test_mesh_spec_parse():
    assert MeshSpec.parse("1x8") == MeshSpec(1, 8)
    assert MeshSpec.parse("2x4") == MeshSpec(2, 4)
    assert MeshSpec.parse("4") == MeshSpec(1, 4)        # bare model width
    assert MeshSpec.parse("2×4") == MeshSpec(2, 4)      # unicode multiply
    assert MeshSpec.parse(MeshSpec(1, 2)) == MeshSpec(1, 2)
    assert MeshSpec(2, 4).size == 8
    with pytest.raises(ValueError):
        MeshSpec.parse("1x2x3")
    with pytest.raises(ValueError):
        MeshSpec.parse("ax2")
    with pytest.raises(ValueError):
        MeshSpec(0, 4)


def test_engine_config_coerces_mesh_strings():
    ec = EngineConfig(mesh="1x2")
    assert ec.mesh == MeshSpec(1, 2)
    assert EngineConfig(mesh=None).mesh is None
    assert EngineConfig(mesh=MeshSpec(1, 4)).mesh == MeshSpec(1, 4)


def test_make_device_mesh_validates_count():
    from repro.launch.mesh import make_device_mesh
    n = jax.device_count()
    mesh = make_device_mesh((1, n), ("data", "model"))
    assert dict(mesh.shape) == {"data": 1, "model": n}
    with pytest.raises(ValueError, match="devices"):
        make_device_mesh((1, n + 1), ("data", "model"))


def test_make_production_mesh_validates_count():
    from repro.launch.mesh import make_production_mesh
    n = jax.device_count()
    mesh = make_production_mesh(shape=(1, n))
    assert mesh.devices.size == n
    with pytest.raises(ValueError, match="device"):
        make_production_mesh(shape=(3, n * 5))


def test_mesh_spec_build_single_device_ok():
    # a 1x1 spec builds on any host — the degenerate mesh used by tests
    mesh = MeshSpec(1, 1).build()
    assert dict(mesh.shape) == {"data": 1, "model": 1}
