"""Benchmark harness — one suite per paper claim/table (see DESIGN.md §6).

E1 blocking sweep (C1/C4)   E2 interconnect (C3)   E3 MOB overlap (C2)
E4 kernel microbench (C1)   E5 edge transformer    E6 roofline table
E7 serving throughput (continuous batching vs seed loop)
E8 kernel_mode sweep (reference vs Pallas vs w8a8, end to end)
"""
import sys
import time


def main() -> None:
    from benchmarks import (blocking_sweep, edge_transformer, interconnect,
                            kernel_bench, kernel_mode_sweep, mob_overlap,
                            roofline_table, serving_throughput)
    suites = [("E1", blocking_sweep), ("E2", interconnect), ("E3", mob_overlap),
              ("E4", kernel_bench), ("E5", edge_transformer),
              ("E6", roofline_table), ("E7", serving_throughput),
              ("E8", kernel_mode_sweep)]
    if len(sys.argv) > 1:
        suites = [(n, m) for n, m in suites if n in sys.argv[1:]]
    for name, mod in suites:
        t0 = time.time()
        lines = mod.run()
        print("\n".join(lines))
        print(f"[{name} done in {time.time()-t0:.1f}s]\n", flush=True)


if __name__ == "__main__":
    main()
