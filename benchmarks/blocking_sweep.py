"""E1 (paper C1/C4): block-wise GEMM data reuse vs block size.

Two layers of the same experiment:
- CGRA analytical model: external-memory words moved & arithmetic intensity
  as the per-PE register tile grows (the paper's sub-matrix blocking knob);
- TPU mapping: VMEM working set + HBM traffic per BlockSpec tile chosen by
  the same mapper (core.cgra.select_block_shapes).
"""
from repro.core.cgra import (CGRAConfig, select_block_shapes, simulate_gemm)


def run() -> list[str]:
    out = ["# E1 blocking sweep — C = A[512,512] @ B[512,512], int8"]
    out.append("rf_words,block,loads_words,AI_macs_per_word,cycles,energy_uJ,power_mW")
    M = K = N = 512
    for rf in (1, 4, 16, 64):
        cfg = CGRAConfig(rf_words=rf)
        r = simulate_gemm(cfg, M, K, N, "int8", blocked=(rf > 1))
        out.append(f"{rf},{r.bm}x{r.bn},{r.loads_words},"
                   f"{r.arithmetic_intensity:.1f},{r.cycles},"
                   f"{r.energy_pj/1e6:.2f},{r.power_mw:.3f}")
    out.append("")
    out.append("# TPU mapping: VMEM tiles for transformer GEMMs (bf16)")
    out.append("gemm,M,K,N,bm,bk,bn,vmem_KiB,hbm_reuse_factor")
    for name, (m, k, n) in {
        "ffn_up_4k": (4096 * 16, 8192, 22016 // 16),
        "attn_qkv": (4096 * 16, 8192, 1024),
        "lm_head": (65536, 8192, 102400 // 16),
    }.items():
        bm, bk, bn = select_block_shapes(m, k, n)
        vmem = (2 * (bm * bk + bk * bn) * 2 + bm * bn * 4) // 1024
        reuse = (bm * bn * bk) / ((bm * bk + bk * bn))  # MACs per word loaded
        out.append(f"{name},{m},{k},{n},{bm},{bk},{bn},{vmem},{reuse:.0f}")
    return out


if __name__ == "__main__":
    print("\n".join(run()))
