"""E7: serving throughput — paged continuous-batching engine vs the seed
per-token Python loop, plus the paged cache's headline capacity win.

Workloads:
- closed batch: same requests all present at t=0, head-to-head tokens/s vs
  the seed-style loop (one fixed batch, Python `for` over decode steps)
- streaming: Poisson arrivals through a small engine (p50/p99 latency)
- prefix reuse: N requests sharing a long common prompt prefix, served at a
  *fixed KV memory budget* — radix page sharing vs no sharing.  Reported:
  prefix-cache hit rate and the max concurrent sequences each mode reaches
  (the paged+radix engine fits the whole batch where slot-equivalent
  allocation fits a fraction).
- goodput under SLO: a Poisson stream of short requests with a 512+-token
  prompt injected mid-stream, chunked prefill (``chunk_tokens=32``) vs
  unchunked — TTFT p50/p99, inter-token-latency p99, and the fraction of
  requests meeting ``--slo-ttft``/``--slo-itl``.  Unchunked, the long
  prefill head-of-line-blocks every in-flight decode for its whole
  duration (an ITL spike); chunked, it streams through the mixed step 32
  tokens per tick and decodes keep flowing.
- degraded mode: a 2x-oversubscribed Poisson burst against a deliberately
  small page pool, ``preemption="recompute"`` vs ``"off"`` — goodput
  (healthy completions/s) plus preemption / rejection / deadline-expiry
  rates.  Recompute admits on prompt-only reservations and resolves pool
  pressure by preempt-and-recompute; off reserves fully up front and sheds
  the same load at the bounded queue instead.
- mesh scaling: re-execs itself with 8 forced host devices and measures
  closed-batch tokens/s plus compiled-HLO bytes-accessed-per-decode-token
  at mesh widths 1/2/4/8 (host-CPU shards share the physical core pool, so
  bytes moved — not tokens/s — is the scaling signal).

``--json PATH`` additionally dumps the headline numbers (tokens/s, prefix
hit rate, concurrency at fixed memory, goodput/TTFT/ITL chunked vs
unchunked) for CI to persist.

    PYTHONPATH=src python benchmarks/serving_throughput.py [--arch olmo-1b]
"""
from __future__ import annotations

import argparse
import json
import time

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs import get_config, reduce_config
from repro.models import model as M
from repro.serving import (Engine, EngineConfig, ServeStats,
                           bytes_tokenizer_encode)

MAX_NEW = 32
N_REQUESTS = 8


def make_workload(cfg, n=N_REQUESTS, seed=0):
    """Mixed prompt lengths, 4..70 bytes."""
    rng = np.random.RandomState(seed)
    return [bytes_tokenizer_encode(f"req {i}: " + "lorem " * rng.randint(1, 12),
                                   cfg.vocab_size) for i in range(n)]


def seed_generate(cfg, params, prompts, max_new=MAX_NEW):
    """The seed engine's decode path: one fixed batch, prefill padded to the
    longest prompt, then a Python loop dispatching one compiled step per
    token (cache capacity pre-padded via ``prefill(cache_len=...)``)."""
    plen = max(len(p) for p in prompts)
    dec = jax.jit(lambda p, c, t, pos: M.decode_step(cfg, p, c, t, pos))
    pre = jax.jit(lambda p, b: M.prefill(cfg, p, b, cache_len=plen + max_new))
    B = len(prompts)
    toks = np.zeros((B, plen), np.int32)
    for i, p in enumerate(prompts):
        toks[i, plen - len(p):] = p
    stats = ServeStats()
    t0 = time.time()
    logits, caches = pre(params, {"tokens": jnp.asarray(toks)})
    jax.block_until_ready(caches)
    stats.prefill_s = time.time() - t0
    out = [list(p) for p in prompts]
    cur = jnp.argmax(logits[:, -1, : cfg.vocab_size], -1).astype(jnp.int32)
    t0 = time.time()
    for step in range(max_new):
        for i in range(B):
            out[i].append(int(cur[i]))
        if step < max_new - 1:
            logits, caches = dec(params, caches, cur[:, None],
                                 jnp.int32(plen + step))
            cur = jnp.argmax(logits[:, -1, : cfg.vocab_size], -1).astype(jnp.int32)
    stats.decode_s = time.time() - t0
    stats.tokens_out = B * max_new
    return out, stats


def bench_closed_batch(cfg, params, prompts):
    """Head-to-head: same 8 requests, all present at t=0."""
    # warm both paths (compile), then time a fresh run
    seed_generate(cfg, params, prompts)
    t0 = time.time()
    _, seed_stats = seed_generate(cfg, params, prompts)
    seed_wall = time.time() - t0

    eng = Engine(cfg, params, EngineConfig(max_len=256,
                                           max_batch=len(prompts),
                                           decode_chunk=8))
    eng.generate(prompts, max_new=MAX_NEW)  # warm (compile)
    t0 = time.time()
    _, cb_stats = eng.generate(prompts, max_new=MAX_NEW)  # per-call deltas
    cb_wall = time.time() - t0
    return seed_stats, seed_wall, cb_stats, cb_wall


def bench_streaming(cfg, params, prompts, rate=4.0):
    """Poisson arrivals at `rate` req/s through a 4-row engine."""
    rng = np.random.RandomState(1)
    eng = Engine(cfg, params, EngineConfig(max_len=256, max_batch=4,
                                           decode_chunk=8))
    eng.generate(prompts[:4], max_new=4)  # warm compiles
    due = np.cumsum(rng.exponential(1.0 / rate, len(prompts)))
    t0, nxt, results = time.time(), 0, []
    while nxt < len(prompts) or eng.num_queued or eng.num_active:
        now = time.time() - t0
        while nxt < len(prompts) and now >= due[nxt]:
            eng.submit(prompts[nxt], MAX_NEW, seed=nxt)
            nxt += 1
        if not (eng.num_queued or eng.num_active):
            time.sleep(min(0.01, max(0.0, due[nxt] - now)))
            continue
        results.extend(eng.step())
    wall = time.time() - t0
    lat = sorted(r.latency_s for r in results)
    ttft = sorted(r.ttft_s for r in results)
    toks = sum(len(r.generated) for r in results)
    return dict(wall=wall, toks=toks, tput=toks / wall,
                p50=lat[len(lat) // 2], p99=lat[-1],
                ttft_p50=ttft[len(ttft) // 2])


def bench_prefix_reuse(cfg, params, n_req=8, prefix_len=512, suffix_len=8,
                       max_new=8, page_size=64):
    """N requests sharing a ``prefix_len``-token prompt prefix, at a fixed
    page-pool budget sized so sharing is the difference between fitting the
    whole batch and fitting a fraction of it."""
    rng = np.random.RandomState(2)
    prefix = rng.randint(1, cfg.vocab_size, prefix_len).tolist()
    prompts = [prefix + rng.randint(1, cfg.vocab_size, suffix_len).tolist()
               for _ in range(n_req)]
    rows = prefix_len + suffix_len + max_new
    pages_per_req = -(-rows // page_size)
    # budget: the shared prefix once + one private tail page per request
    n_pages = 1 + (prefix_len // page_size) + n_req * (
        pages_per_req - prefix_len // page_size)
    max_len = -(-rows // page_size) * page_size
    out = {}
    for label, use_prefix in (("radix", True), ("no_share", False)):
        eng = Engine(cfg, params, EngineConfig(
            max_len=max_len, max_batch=n_req, page_size=page_size,
            n_pages=n_pages, prefix_cache=use_prefix, decode_chunk=8))
        t0 = time.time()
        eng.generate(prompts, max_new=max_new)
        out[label] = dict(wall=time.time() - t0,
                          max_concurrent=eng.stats.peak_active,
                          hit_rate=eng.prefix_hit_rate)
    out["kv_rows_budget"] = (n_pages - 1) * page_size
    return out


def bench_degraded(cfg, params, preemption, *, n_req=16, rate=400.0,
                   max_new=24, page_size=16, n_pages=10, max_batch=6,
                   max_queue=4, deadline_s=5.0, seed=9):
    """2x-oversubscribed Poisson burst against a deliberately small page
    pool (9 usable pages vs an 18-page full-reservation demand at
    ``max_batch``).  ``preemption="recompute"`` admits on prompt-only
    reservations and resolves pool pressure by preempt-and-recompute;
    ``"off"`` reserves fully up front and sheds the same load at the
    bounded queue.  Nothing is silently dropped either way — every request
    comes back with a :class:`FinishReason`."""
    rng = np.random.RandomState(seed)
    prompts = [rng.randint(1, cfg.vocab_size, rng.randint(12, 28)).tolist()
               for _ in range(n_req)]
    eng = Engine(cfg, params, EngineConfig(
        max_len=4 * page_size, max_batch=max_batch, page_size=page_size,
        n_pages=n_pages, decode_chunk=4, chunk_tokens=16,
        max_queue=max_queue, deadline_s=deadline_s, preemption=preemption))
    eng.generate(prompts[:2], max_new=4)  # warm compiles
    base = (eng.stats.preempted, eng.stats.rejected,
            eng.stats.deadline_expired)
    due = np.cumsum(rng.exponential(1.0 / rate, n_req))
    t0, nxt, results = time.time(), 0, []
    while nxt < n_req or eng.num_queued or eng.num_active:
        now = time.time() - t0
        while nxt < n_req and now >= due[nxt]:
            eng.submit(prompts[nxt], max_new, seed=nxt)
            nxt += 1
        if not (eng.num_queued or eng.num_active):
            time.sleep(min(0.01, max(0.0, due[nxt] - now)))
            continue
        results.extend(eng.step())
    results.extend(eng.run())
    wall = time.time() - t0
    ok = [r for r in results if r.ok]
    return dict(
        wall=wall, total=len(results), completed=len(ok),
        goodput_req_s=len(ok) / wall,
        goodput_tok_s=sum(len(r.generated) for r in ok) / wall,
        preempted=eng.stats.preempted - base[0],
        rejected=eng.stats.rejected - base[1],
        deadline_expired=eng.stats.deadline_expired - base[2])


def bench_mesh_child(arch: str) -> dict:
    """Runs inside the 8-forced-device subprocess: closed-batch throughput
    and compiled decode bytes-per-token at mesh widths 1/2/4/8."""
    from repro.serving import MeshSpec
    cfg = reduce_config(get_config(arch))
    params = M.init(cfg, jax.random.PRNGKey(0))
    prompts = make_workload(cfg, n=4)
    out = {"devices": jax.device_count(), "widths": {}}
    for m in (1, 2, 4, 8):
        if m > jax.device_count():
            continue
        eng = Engine(cfg, params, EngineConfig(
            max_len=256, max_batch=4, decode_chunk=4,
            mesh=None if m == 1 else MeshSpec(1, m)))
        eng.generate(prompts, max_new=8)                 # warm (compile)
        t0 = time.time()
        _, stats = eng.generate(prompts, max_new=MAX_NEW)
        wall = time.time() - t0
        runner, sched = eng.runner, eng.sched
        lowered = runner.decode_fn.lower(
            runner.params, runner.caches, jnp.asarray(sched.pages),
            jnp.asarray(sched.cur), jnp.asarray(sched.pos),
            jnp.asarray(sched.remaining), jnp.asarray(sched.temp),
            jnp.asarray(sched.keys),
            jnp.zeros(eng.config.max_batch, jnp.bool_))
        ca = lowered.compile().cost_analysis()
        if isinstance(ca, (list, tuple)):               # older jax spelling
            ca = ca[0] if ca else {}
        toks_per_call = eng.config.decode_chunk * eng.config.max_batch
        out["widths"][str(m)] = dict(
            decode_tokens_per_s=round(stats.tokens_per_s, 2),
            end_to_end_tokens_per_s=round(4 * MAX_NEW / wall, 2),
            decode_bytes_per_token=round(
                float(ca.get("bytes accessed", 0.0)) / toks_per_call),
        )
    return out


def bench_mesh_scaling(arch: str) -> dict:
    """Re-exec this script with 8 forced host devices (the parent process
    must keep its single-device view) and collect the child's JSON."""
    import os
    import subprocess
    import sys
    src = os.path.join(os.path.dirname(os.path.abspath(__file__)), "..",
                       "src")
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=8")
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    try:
        res = subprocess.run(
            [sys.executable, os.path.abspath(__file__), "--mesh-child",
             "--arch", arch],
            env=env, capture_output=True, text=True, timeout=1200)
        if res.returncode != 0:
            return {"error": (res.stderr or res.stdout)[-500:]}
        return json.loads(res.stdout.strip().splitlines()[-1])
    except (subprocess.TimeoutExpired, json.JSONDecodeError, OSError) as e:
        return {"error": repr(e)}


def _pctl(xs, q):
    xs = sorted(xs)
    return xs[min(len(xs) - 1, int(q * len(xs)))] if xs else 0.0


def bench_goodput(cfg, params, chunk_tokens, *, slo_ttft_s=2.0,
                  slo_itl_s=0.25, rate=50.0, n_short=12, long_len=560,
                  max_new=16, seed=3):
    """Poisson stream of short requests with one ``long_len``-token prompt
    injected mid-stream; measures what chunked prefill buys the *other*
    requests: TTFT/ITL tails and SLO-goodput."""
    rng = np.random.RandomState(seed)
    shorts = [rng.randint(1, cfg.vocab_size, rng.randint(8, 40)).tolist()
              for _ in range(n_short)]
    long_prompt = rng.randint(1, cfg.vocab_size, long_len).tolist()
    eng = Engine(cfg, params, EngineConfig(
        max_len=long_len + max_new, max_batch=8, page_size=64,
        chunk_tokens=chunk_tokens, decode_chunk=4,
        slo_ttft_s=slo_ttft_s, slo_itl_s=slo_itl_s))
    # warm both tick shapes (compile): a long prefill and a short batch
    eng.generate([long_prompt], max_new=2)
    eng.generate(shorts[:4], max_new=4)

    due = np.cumsum(rng.exponential(1.0 / rate, n_short)).tolist()
    arrivals = sorted([(t, p) for t, p in zip(due, shorts)] +
                      [(due[n_short // 3], long_prompt)])
    t0, nxt, results = time.time(), 0, []
    while nxt < len(arrivals) or eng.num_queued or eng.num_active:
        now = time.time() - t0
        while nxt < len(arrivals) and now >= arrivals[nxt][0]:
            eng.submit(arrivals[nxt][1], max_new, seed=nxt)
            nxt += 1
        if not (eng.num_queued or eng.num_active):
            time.sleep(min(0.01, max(0.0, arrivals[nxt][0] - now)))
            continue
        results.extend(eng.step())
    wall = time.time() - t0

    ttft = [r.ttft_s for r in results]
    itl = [g for r in results for g in r.itl_s]
    good = sum(1 for r in results
               if r.ttft_s <= slo_ttft_s
               and all(g <= slo_itl_s for g in r.itl_s))
    toks = sum(len(r.generated) for r in results)
    return dict(wall=wall, toks=toks, tput=toks / wall,
                ttft_p50=_pctl(ttft, 0.5), ttft_p99=_pctl(ttft, 0.99),
                itl_p99=_pctl(itl, 0.99),
                goodput_frac=good / len(results),
                goodput_req_s=good / wall)


def run(arch: str = "olmo-1b", slo_ttft_s: float = 2.0,
        slo_itl_s: float = 0.25) -> tuple[list[str], dict]:
    cfg = reduce_config(get_config(arch))
    params = M.init(cfg, jax.random.PRNGKey(0))
    prompts = make_workload(cfg)
    out = [f"# E7 serving throughput ({cfg.name}, {N_REQUESTS} mixed-length "
           f"requests x {MAX_NEW} new tokens)"]

    seed_stats, seed_wall, cb_stats, cb_wall = bench_closed_batch(
        cfg, params, prompts)
    out.append("engine,decode_tok_s,end_to_end_tok_s,wall_s")
    n_tok = N_REQUESTS * MAX_NEW
    out.append(f"seed_loop,{seed_stats.tokens_per_s:.1f},"
               f"{n_tok / seed_wall:.1f},{seed_wall:.2f}")
    out.append(f"continuous_scan,{cb_stats.tokens_per_s:.1f},"
               f"{n_tok / cb_wall:.1f},{cb_wall:.2f}")
    speedup = seed_wall / cb_wall
    out.append(f"derived: scan-based continuous batching is {speedup:.2f}x the "
               f"seed loop end-to-end (per-step Python dispatch and cache "
               f"re-padding eliminated)")

    s = bench_streaming(cfg, params, prompts)
    out.append("streaming (Poisson 4 req/s, 4 batch rows): "
               f"{s['tput']:.1f} tok/s p50={s['p50']:.2f}s p99={s['p99']:.2f}s "
               f"ttft_p50={s['ttft_p50']:.2f}s")

    SLO_TTFT, SLO_ITL = slo_ttft_s, slo_itl_s
    gp = {label: bench_goodput(cfg, params, ct, slo_ttft_s=SLO_TTFT,
                               slo_itl_s=SLO_ITL)
          for label, ct in (("chunked", 32), ("unchunked", None))}
    out.append(f"goodput under SLO (ttft<={SLO_TTFT}s, itl<={SLO_ITL}s; "
               f"Poisson shorts + one 560-token prompt mid-stream):")
    for label, g in gp.items():
        out.append(f"  {label}: goodput={g['goodput_frac']:.0%} "
                   f"ttft_p50={g['ttft_p50']:.3f}s "
                   f"ttft_p99={g['ttft_p99']:.3f}s "
                   f"itl_p99={g['itl_p99']:.3f}s {g['tput']:.1f} tok/s")
    out.append(f"derived: chunked prefill cuts inter-token p99 "
               f"{gp['unchunked']['itl_p99'] / max(gp['chunked']['itl_p99'], 1e-9):.1f}x "
               f"(the long prefill no longer head-of-line-blocks decodes)")

    dg = {mode: bench_degraded(cfg, params, mode)
          for mode in ("recompute", "off")}
    out.append("degraded mode (2x-oversubscribed Poisson burst, 9-page "
               "pool, bounded queue):")
    for mode, d in dg.items():
        out.append(f"  preemption={mode}: goodput={d['goodput_req_s']:.1f} "
                   f"req/s ({d['goodput_tok_s']:.1f} tok/s) "
                   f"completed={d['completed']}/{d['total']} "
                   f"preempted={d['preempted']} rejected={d['rejected']} "
                   f"deadline={d['deadline_expired']}")
    out.append("derived: recompute-preemption trades repeat prefill work "
               "(cheap — radix hits cover the recompute) for admission at "
               "prompt-only reservations; full up-front reservation sheds "
               "the same burst at the bounded queue instead")

    pr = bench_prefix_reuse(cfg, params)
    out.append(f"prefix reuse (8 reqs sharing a 512-token prefix, "
               f"{pr['kv_rows_budget']} KV rows total): "
               f"radix max_concurrent={pr['radix']['max_concurrent']} "
               f"hit_rate={pr['radix']['hit_rate']:.2f} | no_share "
               f"max_concurrent={pr['no_share']['max_concurrent']}")

    ms = bench_mesh_scaling(arch)
    if "error" in ms:
        out.append(f"mesh scaling: skipped ({ms['error'][:120]})")
    else:
        out.append(f"mesh scaling (8 forced host devices, 1xM model-parallel, "
                   f"4 reqs x {MAX_NEW} new tokens; bytes from compiled "
                   f"decode HLO cost analysis):")
        out.append("  mesh,decode_tok_s,end_to_end_tok_s,decode_bytes_per_tok")
        for m, row in sorted(ms["widths"].items(), key=lambda kv: int(kv[0])):
            out.append(f"  1x{m},{row['decode_tokens_per_s']},"
                       f"{row['end_to_end_tokens_per_s']},"
                       f"{row['decode_bytes_per_token']}")
        out.append("derived: host-CPU mesh widths share one physical core "
                   "pool, so tokens/s measures sharding overhead, not "
                   "speedup; bytes-per-token is the real signal (per-device "
                   "weight traffic should fall as 1/M for the sharded "
                   "projections)")

    blob = dict(
        arch=cfg.name,
        decode_tokens_per_s=round(cb_stats.tokens_per_s, 2),
        seed_decode_tokens_per_s=round(seed_stats.tokens_per_s, 2),
        end_to_end_speedup=round(speedup, 3),
        streaming_p50_s=round(s["p50"], 3),
        streaming_p99_s=round(s["p99"], 3),
        prefix_hit_rate=round(pr["radix"]["hit_rate"], 4),
        max_concurrent_radix=pr["radix"]["max_concurrent"],
        max_concurrent_no_share=pr["no_share"]["max_concurrent"],
        kv_rows_budget=pr["kv_rows_budget"],
        slo_ttft_s=SLO_TTFT,
        slo_itl_s=SLO_ITL,
        chunked_ttft_p50_s=round(gp["chunked"]["ttft_p50"], 4),
        chunked_ttft_p99_s=round(gp["chunked"]["ttft_p99"], 4),
        chunked_itl_p99_s=round(gp["chunked"]["itl_p99"], 4),
        chunked_goodput_frac=round(gp["chunked"]["goodput_frac"], 4),
        unchunked_ttft_p50_s=round(gp["unchunked"]["ttft_p50"], 4),
        unchunked_ttft_p99_s=round(gp["unchunked"]["ttft_p99"], 4),
        unchunked_itl_p99_s=round(gp["unchunked"]["itl_p99"], 4),
        unchunked_goodput_frac=round(gp["unchunked"]["goodput_frac"], 4),
        degraded={mode: {k: (round(v, 4) if isinstance(v, float) else int(v))
                         for k, v in d.items()}
                  for mode, d in dg.items()},
        mesh_scaling=ms,
    )
    return out, blob


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="olmo-1b")
    ap.add_argument("--json", default=None,
                    help="also dump headline numbers to this JSON path")
    ap.add_argument("--slo-ttft", type=float, default=2.0,
                    help="time-to-first-token SLO (s) for goodput")
    ap.add_argument("--slo-itl", type=float, default=0.25,
                    help="inter-token-latency SLO (s) for goodput")
    ap.add_argument("--mesh-child", action="store_true",
                    help=argparse.SUPPRESS)  # internal: 8-device re-exec
    args = ap.parse_args()
    if args.mesh_child:
        print(json.dumps(bench_mesh_child(args.arch)))
        return
    lines, blob = run(args.arch, slo_ttft_s=args.slo_ttft,
                      slo_itl_s=args.slo_itl)
    print("\n".join(lines))
    if args.json:
        with open(args.json, "w") as f:
            json.dump(blob, f, indent=2, sort_keys=True)
        print(f"wrote {args.json}")


if __name__ == "__main__":
    main()
