"""E7: serving throughput — continuous-batching scan engine vs the seed
per-token Python loop.

Workload: a mixed-prompt-length batch of requests under a Poisson arrival
process (streamed into the engine as slots free up), plus a closed all-at-once
batch for the head-to-head tokens/s comparison against the seed-style loop
(one fixed batch, Python `for` over decode steps, `grow_cache` padding).

Reported: tokens/s for both paths, speedup, and p50/p99 request latency under
the streaming workload.

    PYTHONPATH=src python benchmarks/serving_throughput.py [--arch olmo-1b]
"""
from __future__ import annotations

import argparse
import time

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs import get_config, reduce_config
from repro.models import model as M
from repro.serving.engine import (Engine, ServeStats, bytes_tokenizer_encode,
                                  grow_cache)

MAX_NEW = 32
N_REQUESTS = 8


def make_workload(cfg, n=N_REQUESTS, seed=0):
    """Mixed prompt lengths, 4..70 bytes."""
    rng = np.random.RandomState(seed)
    return [bytes_tokenizer_encode(f"req {i}: " + "lorem " * rng.randint(1, 12),
                                   cfg.vocab_size) for i in range(n)]


def seed_generate(cfg, params, prompts, max_new=MAX_NEW):
    """The seed engine's decode path: one fixed batch, prefill, grow_cache,
    then a Python loop dispatching one compiled step per token."""
    dec = jax.jit(lambda p, c, t, pos: M.decode_step(cfg, p, c, t, pos))
    pre = jax.jit(lambda p, b: M.prefill(cfg, p, b))
    B = len(prompts)
    plen = max(len(p) for p in prompts)
    toks = np.zeros((B, plen), np.int32)
    for i, p in enumerate(prompts):
        toks[i, plen - len(p):] = p
    stats = ServeStats()
    t0 = time.time()
    logits, caches = pre(params, {"tokens": jnp.asarray(toks)})
    caches = grow_cache(cfg, caches, plen + max_new)
    jax.block_until_ready(caches)
    stats.prefill_s = time.time() - t0
    out = [list(p) for p in prompts]
    cur = jnp.argmax(logits[:, -1, : cfg.vocab_size], -1).astype(jnp.int32)
    t0 = time.time()
    for step in range(max_new):
        for i in range(B):
            out[i].append(int(cur[i]))
        if step < max_new - 1:
            logits, caches = dec(params, caches, cur[:, None],
                                 jnp.int32(plen + step))
            cur = jnp.argmax(logits[:, -1, : cfg.vocab_size], -1).astype(jnp.int32)
    stats.decode_s = time.time() - t0
    stats.tokens_out = B * max_new
    return out, stats


def bench_closed_batch(cfg, params, prompts):
    """Head-to-head: same 8 requests, all present at t=0."""
    # warm both paths (compile), then time a fresh run
    seed_generate(cfg, params, prompts)
    t0 = time.time()
    _, seed_stats = seed_generate(cfg, params, prompts)
    seed_wall = time.time() - t0

    eng = Engine(cfg, params, max_len=256, max_slots=len(prompts),
                 prefill_bucket=32, decode_chunk=8)
    eng.generate(prompts, max_new=MAX_NEW)  # warm (compile)
    t0 = time.time()
    _, cb_stats = eng.generate(prompts, max_new=MAX_NEW)  # per-call deltas
    cb_wall = time.time() - t0
    return seed_stats, seed_wall, cb_stats, cb_wall


def bench_streaming(cfg, params, prompts, rate=4.0):
    """Poisson arrivals at `rate` req/s through a 4-slot engine."""
    rng = np.random.RandomState(1)
    eng = Engine(cfg, params, max_len=256, max_slots=4, prefill_bucket=32,
                 decode_chunk=8)
    eng.generate(prompts[:4], max_new=4)  # warm compiles
    due = np.cumsum(rng.exponential(1.0 / rate, len(prompts)))
    t0, nxt, results = time.time(), 0, []
    while nxt < len(prompts) or eng.num_queued or eng.num_active:
        now = time.time() - t0
        while nxt < len(prompts) and now >= due[nxt]:
            eng.submit(prompts[nxt], MAX_NEW, seed=nxt)
            nxt += 1
        if not (eng.num_queued or eng.num_active):
            time.sleep(min(0.01, max(0.0, due[nxt] - now)))
            continue
        results.extend(eng.step())
    wall = time.time() - t0
    lat = sorted(r.latency_s for r in results)
    ttft = sorted(r.ttft_s for r in results)
    toks = sum(len(r.generated) for r in results)
    return dict(wall=wall, toks=toks, tput=toks / wall,
                p50=lat[len(lat) // 2], p99=lat[-1],
                ttft_p50=ttft[len(ttft) // 2])


def run(arch: str = "olmo-1b") -> list[str]:
    cfg = reduce_config(get_config(arch))
    params = M.init(cfg, jax.random.PRNGKey(0))
    prompts = make_workload(cfg)
    out = [f"# E7 serving throughput ({cfg.name}, {N_REQUESTS} mixed-length "
           f"requests x {MAX_NEW} new tokens)"]

    seed_stats, seed_wall, cb_stats, cb_wall = bench_closed_batch(
        cfg, params, prompts)
    out.append("engine,decode_tok_s,end_to_end_tok_s,wall_s")
    n_tok = N_REQUESTS * MAX_NEW
    out.append(f"seed_loop,{seed_stats.tokens_per_s:.1f},"
               f"{n_tok / seed_wall:.1f},{seed_wall:.2f}")
    out.append(f"continuous_scan,{cb_stats.tokens_per_s:.1f},"
               f"{n_tok / cb_wall:.1f},{cb_wall:.2f}")
    speedup = seed_wall / cb_wall
    out.append(f"derived: scan-based continuous batching is {speedup:.2f}x the "
               f"seed loop end-to-end (per-step Python dispatch + grow_cache "
               f"padding eliminated)")

    s = bench_streaming(cfg, params, prompts)
    out.append("streaming (Poisson 4 req/s, 4 slots): "
               f"{s['tput']:.1f} tok/s p50={s['p50']:.2f}s p99={s['p99']:.2f}s "
               f"ttft_p50={s['ttft_p50']:.2f}s")
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="olmo-1b")
    args = ap.parse_args()
    print("\n".join(run(args.arch)))


if __name__ == "__main__":
    main()
