"""E3 (paper C2): decoupled MOB LOAD/STORE vs serialized memory access —
PE idle cycles across arithmetic-intensity regimes."""
from repro.core.cgra import CGRAConfig, simulate_gemm


def run() -> list[str]:
    out = ["# E3 MOB decoupling — PE stall cycles with/without prefetch overlap"]
    out.append("gemm,AI,decoupled_cycles,serialized_cycles,speedup,"
               "pe_util_decoupled,pe_util_serialized")
    dec, ser = CGRAConfig(decoupled_mob=True), CGRAConfig(decoupled_mob=False)
    cases = {
        "square_512": (512, 512, 512),
        "skinny_gemv": (512, 512, 1),    # decode-like, memory-bound
        "attn_scores": (128 * 4, 64, 128),
        "ffn_up": (128, 256, 1024),
    }
    for name, (m, k, n) in cases.items():
        a = simulate_gemm(dec, m, k, n, "int8")
        b = simulate_gemm(ser, m, k, n, "int8")
        out.append(f"{name},{a.arithmetic_intensity:.1f},{a.cycles},{b.cycles},"
                   f"{b.cycles/a.cycles:.2f},{a.pe_utilization:.2f},"
                   f"{b.pe_utilization:.2f}")
    out.append("derived: overlap converts (compute+mem) into max(compute,mem); "
               "biggest wins exactly where the paper claims — memory-bound "
               "GEMV/attention shapes")
    return out


if __name__ == "__main__":
    print("\n".join(run()))
