"""End-to-end kernel_mode sweep on the edge-transformer config.

Runs the full model (forward + prefill + steady-state decode) on
``cgra-edge`` under every execution mode the kernel stack supports and
reports wall time plus accuracy against the fp32 reference path:

- ``reference``          — jnp einsum/matmul oracle
- ``interpret``          — Pallas CGRA kernels through the interpreter (CPU;
                           validates the exact kernel math, not a speed run)
- ``pallas``             — compiled TPU kernels (skipped off-TPU)
- ``w8a8 reference``     — int8 weights + dynamic int8 activations, jnp int32
                           accumulation (the packed-data edge scenario)
- ``w8a8 interpret/pallas`` — same, through ``block_gemm_int8``'s fused
                           dequant epilogue

The decode column is the serving steady state: a batch of ``--slots``
sequences prefilled to ``--seq``, then ``--decode-steps`` single-token
``decode_step`` calls fused in a ``lax.scan`` (the engine's decode-chunk
shape), reported as decoded tokens/s per kernel_mode — flash-decode reads
only the live cache region, so this is the number the decode kernel moves.

    PYTHONPATH=src python benchmarks/kernel_mode_sweep.py [--seq 64] \
        [--iters 3] [--slots 4] [--decode-steps 8]
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import model as M


def _time(fn, iters: int) -> float:
    jax.block_until_ready(fn())  # compile
    t0 = time.time()
    for _ in range(iters):
        jax.block_until_ready(fn())
    return (time.time() - t0) / iters * 1e3  # ms


def _decode_steady_state_fn(cfg, params, slots: int, seq: int, steps: int):
    """Build the engine-shaped decode chunk: prefill ``slots`` sequences to
    ``seq`` rows of a ``[slots, seq + steps]`` cache, then scan ``steps``
    fused single-token decodes.  Returns (jitted fn over (params, caches),
    initial caches, tokens/s divisor)."""
    from jax import lax

    toks = jax.random.randint(jax.random.PRNGKey(2), (slots, seq), 0,
                              cfg.vocab_size)
    _, caches = M.prefill(cfg, params, {"tokens": toks},
                          cache_len=seq + steps)
    pos0 = jnp.full((slots,), seq, jnp.int32)
    cur0 = toks[:, -1]

    def chunk(p, c):
        def body(carry, _):
            c, cur, pos = carry
            logits, c = M.decode_step(cfg, p, c, cur[:, None], pos)
            nxt = jnp.argmax(
                logits[:, -1, : cfg.vocab_size], -1).astype(jnp.int32)
            return (c, nxt, pos + 1), nxt

        (_, _, _), out = lax.scan(body, (c, cur0, pos0), None, length=steps)
        return out

    return jax.jit(chunk), caches, slots * steps


def run(seq: int = 64, iters: int = 3, slots: int = 4,
        decode_steps: int = 8) -> list[str]:
    cfg = get_config("cgra-edge")
    params = M.init(cfg, jax.random.PRNGKey(0))
    params_q = M.quantize_params(cfg, params)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (1, seq), 0,
                                cfg.vocab_size)
    batch = {"tokens": tokens}
    on_tpu = jax.default_backend() == "tpu"

    def logits_fn(c, p):
        def f():
            hidden, _, _ = M.forward_hidden(c, p, batch, mode="train")
            return M.lm_logits(c, p, hidden)
        return f

    ref = np.asarray(logits_fn(cfg, params)(), np.float32)
    ref_argmax = np.argmax(ref[:, :, : cfg.vocab_size], -1)

    out = [f"# kernel_mode sweep — {cfg.name}, B=1 S={seq}, "
           f"decode: {slots} slots x {decode_steps} steps, "
           f"backend={jax.default_backend()}"]
    out.append("mode,forward_ms,prefill_ms,decode_toks_per_s,"
               "max_abs_dlogits,argmax_agree")
    sweep = [("reference", cfg, params), ("interpret",
             cfg.with_(kernel_mode="interpret"), params)]
    if on_tpu:
        sweep.append(("pallas", cfg.with_(kernel_mode="pallas"), params))
    sweep.append(("w8a8 reference", cfg.with_(quant="w8a8"), params_q))
    sweep.append(("w8a8 interpret",
                  cfg.with_(quant="w8a8", kernel_mode="interpret"), params_q))
    if on_tpu:
        sweep.append(("w8a8 pallas",
                      cfg.with_(quant="w8a8", kernel_mode="pallas"), params_q))

    for name, c, p in sweep:
        lg = np.asarray(logits_fn(c, p)(), np.float32)
        dmax = float(np.max(np.abs(lg - ref)))
        agree = float(np.mean(np.argmax(lg[:, :, : cfg.vocab_size], -1)
                              == ref_argmax))
        fwd_ms = _time(jax.jit(logits_fn(c, p)), iters)
        pre_ms = _time(jax.jit(lambda c=c, p=p: M.prefill(c, p, batch)[0]),
                       iters)
        dec_fn, caches, ntoks = _decode_steady_state_fn(
            c, p, slots, seq, decode_steps)
        dec_ms = _time(lambda: dec_fn(p, caches), iters)
        toks_s = ntoks / (dec_ms / 1e3)
        out.append(f"{name},{fwd_ms:.1f},{pre_ms:.1f},{toks_s:.0f},"
                   f"{dmax:.2e},{agree:.3f}")
    if not on_tpu:
        out.append("# pallas (compiled) modes skipped: no TPU backend; "
                   "interpret mode executes the identical kernel math")
    return out


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--iters", type=int, default=3)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--decode-steps", type=int, default=8)
    a = ap.parse_args()
    print("\n".join(run(a.seq, a.iters, a.slots, a.decode_steps)))
