"""E2 (paper C3): switchless mesh-torus vs switched NoC, at both scales.

Edge scale: first-order energy/latency from the CGRA model.
Pod scale: lowered-HLO comparison of the torus ring schedule
(collective_permute chain) vs XLA's default all-gather for the same
tensor-parallel GEMM, on an 8-way fake mesh (subprocess-free: this module is
run by benchmarks.run inside the main process, which keeps 1 device — so the
pod-scale part shells out).
"""
import os
import re
import subprocess
import sys
import textwrap

from repro.core.cgra import CGRAConfig, simulate_transformer_layer

_POD_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np, re
    try:
        from jax.experimental.shard_map import shard_map
    except ImportError:  # newer jax moved it to the top level
        from jax import shard_map
    from jax.sharding import PartitionSpec as P
    from repro.core import torus
    from repro.launch.mesh import make_mesh

    mesh = make_mesh((8,), ("model",))
    T, D, F = 1024, 512, 2048
    x = jax.ShapeDtypeStruct((T, D), jnp.bfloat16)
    w = jax.ShapeDtypeStruct((D, F), jnp.bfloat16)

    ring = shard_map(lambda xs, ws: torus.ring_allgather_matmul(xs, ws),
                     mesh=mesh, in_specs=(P("model", None), P(None, "model")),
                     out_specs=P(None, "model"))
    t_ring = jax.jit(ring).lower(x, w).compile().as_text()

    def xla_default(xs, ws):
        return jnp.matmul(xs, ws)  # x token-sharded -> XLA all-gathers
    f2 = jax.jit(xla_default,
                 in_shardings=(jax.NamedSharding(mesh, P("model", None)),
                               jax.NamedSharding(mesh, P(None, "model"))),
                 out_shardings=jax.NamedSharding(mesh, P(None, "model")))
    t_xla = f2.lower(x, w).compile().as_text()

    def stats(txt):
        return {k: len(re.findall(k, txt))
                for k in ("all-gather", "collective-permute", "all-reduce")}
    print("ring", stats(t_ring))
    print("xla ", stats(t_xla))
""")


def run() -> list[str]:
    out = ["# E2 interconnect — edge scale (CGRA model, BERT-tiny layer, seq 128)"]
    out.append("interconnect,cycles,energy_uJ,power_mW,hop_energy_share")
    for name, cfg in (("switchless_torus", CGRAConfig()),
                      ("switched_noc", CGRAConfig(switched_noc=True))):
        tot, _ = simulate_transformer_layer(cfg, 256, 4, 64, 1024, seq=128)
        e_link = cfg.e_hop_word + (cfg.e_router_word if cfg.switched_noc else 0)
        hop_pj = tot.hops_words * e_link
        out.append(f"{name},{tot.cycles},{tot.energy_pj/1e6:.2f},"
                   f"{tot.power_mw:.3f},{hop_pj/tot.energy_pj:.3f}")
    t = simulate_transformer_layer(CGRAConfig(), 256, 4, 64, 1024, seq=128)[0]
    s = simulate_transformer_layer(CGRAConfig(switched_noc=True), 256, 4, 64,
                                   1024, seq=128)[0]
    out.append(f"derived: switchless saves {100*(1 - t.energy_pj/s.energy_pj):.1f}% "
               f"energy, {100*(1 - t.cycles/s.cycles):.2f}% latency (first-order)")

    out.append("")
    out.append("# E2 pod scale — HLO collective schedule, TP GEMM on 8-way mesh")
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    env.pop("XLA_FLAGS", None)
    res = subprocess.run([sys.executable, "-c", _POD_SCRIPT], env=env,
                         capture_output=True, text=True, timeout=600)
    out.extend((res.stdout or res.stderr).strip().splitlines())
    out.append("derived: the torus schedule issues only neighbor "
               "collective-permutes (overlappable per-step), zero all-gathers")
    return out


if __name__ == "__main__":
    print("\n".join(run()))
