"""E4 (paper C1): block-GEMM / flash-attention kernel microbench.

On this CPU container the Pallas kernels execute in interpret mode (Python),
so wall-clock is NOT the metric; we report (a) allclose vs oracle, (b) the
reference-path jnp wall time as the CPU baseline, and (c) modeled TPU v5e
time from the roofline (max of MXU time and HBM time for the chosen tiles).
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.cgra import select_block_shapes
from repro.core.quant import quantize
from repro.kernels import ref
from repro.kernels.block_gemm import block_gemm
from repro.kernels.flash_attention import flash_attention

PEAK = 197e12
HBM = 819e9


def _time(fn, *args, n=5):
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else \
        jax.block_until_ready(fn(*args))
    t0 = time.time()
    for _ in range(n):
        jax.block_until_ready(fn(*args))
    return (time.time() - t0) / n * 1e6


def run() -> list[str]:
    rng = np.random.RandomState(0)
    out = ["# E4 kernel microbench"]
    out.append("name,us_per_call,derived")
    for (m, k, n) in [(512, 512, 512), (1024, 2048, 1024)]:
        a = jnp.asarray(rng.randn(m, k), jnp.float32)
        b = jnp.asarray(rng.randn(k, n), jnp.float32)
        got = block_gemm(a, b, block_shape=(128, 128, 128), interpret=True)
        ok = np.allclose(np.asarray(got), np.asarray(ref.block_gemm_ref(a, b)),
                         atol=1e-2)
        us = _time(jax.jit(lambda x, y: ref.block_gemm_ref(x, y)), a, b)
        bm, bk, bn = select_block_shapes(m, k, n, 4)
        flops = 2 * m * k * n
        bytes_ = (m * k + k * n + m * n) * 4
        t_tpu = max(flops / PEAK, bytes_ / HBM) * 1e6
        out.append(f"block_gemm_{m}x{k}x{n},{us:.0f},"
                   f"allclose={ok} tile=({bm}.{bk}.{bn}) model_tpu_us={t_tpu:.1f}")
    B, H, S, D = 1, 4, 512, 64
    q = jnp.asarray(rng.randn(B, H, S, D) * .3, jnp.float32)
    kk = jnp.asarray(rng.randn(B, H, S, D) * .3, jnp.float32)
    v = jnp.asarray(rng.randn(B, H, S, D) * .3, jnp.float32)
    got = flash_attention(q, kk, v, causal=True, bq=128, bk=128, interpret=True)
    ok = np.allclose(np.asarray(got),
                     np.asarray(ref.flash_attention_ref(q, kk, v, causal=True)),
                     atol=2e-3)
    us = _time(jax.jit(lambda a1, a2, a3: ref.flash_attention_ref(
        a1, a2, a3, causal=True)), q, kk, v)
    flops = 4 * B * H * S * S * D
    t_tpu = max(flops / PEAK, (3 * B * H * S * D * 4) / HBM) * 1e6
    out.append(f"flash_attn_{B}x{H}x{S}x{D},{us:.0f},"
               f"allclose={ok} model_tpu_us={t_tpu:.1f}")

    a = rng.randn(512, 512).astype(np.float32)
    b = rng.randn(512, 512).astype(np.float32)
    aq = quantize(jnp.asarray(a), axis=0)
    bq = quantize(jnp.asarray(b), axis=-1)
    from repro.kernels.block_gemm import block_gemm_int8
    got = block_gemm_int8(aq.q, bq.q, aq.scale, bq.scale.reshape(1, -1),
                          block_shape=(128, 128, 128), interpret=True)
    rel = np.median(np.abs(np.asarray(got) - a @ b) / (np.abs(a @ b) + 1))
    out.append(f"block_gemm_int8_512,0,median_rel_err={rel:.4f} (w8a8 packed path)")
    return out


if __name__ == "__main__":
    print("\n".join(run()))
