"""E6: aggregate the dry-run JSONs into the EXPERIMENTS.md roofline tables."""
import glob
import json
import os

OUT = os.path.join(os.path.dirname(__file__), "..", "out", "dryrun")


def load(mesh: str, tag: str | None = None) -> list[dict]:
    rows = []
    for f in sorted(glob.glob(os.path.join(OUT, mesh, "*.json"))):
        name = os.path.basename(f)[:-5]
        if tag is None and name.count("--") >= 2:
            continue
        if tag is not None and not name.endswith(f"--{tag}"):
            continue
        rows.append(json.load(open(f)))
    return rows


def fmt_table(rows: list[dict]) -> list[str]:
    out = ["| arch | shape | peak GiB/dev | t_comp (s) | t_mem (s) | t_coll (s) "
           "| bottleneck | useful | roofline frac |",
           "|---|---|---|---|---|---|---|---|---|"]
    for d in rows:
        if "skipped" in d:
            out.append(f"| {d['arch']} | {d['shape']} | — | — | — | — | "
                       f"skip: {d['skipped'][:48]} | — | — |")
            continue
        if "error" in d:
            out.append(f"| {d['arch']} | {d['shape']} | ERROR {d['error'][:40]} |")
            continue
        r = d.get("roofline")
        m = d["memory"]["peak_per_device_gib"]
        if not r:
            out.append(f"| {d['arch']} | {d['shape']} | {m} | compiled (multi-pod "
                       f"pass, costs single-pod only) | | | | | |")
            continue
        out.append(
            f"| {d['arch']} | {d['shape']} | {m:.1f} | {r['t_compute_s']:.2f} | "
            f"{r['t_memory_s']:.2f} | {r['t_collective_s']:.2f} | "
            f"{r['bottleneck']} | {r['useful_ratio']:.2f} | "
            f"{r['roofline_fraction']:.4f} |")
    return out


def run() -> list[str]:
    out = ["# E6 roofline table (single-pod 16x16, baseline)"]
    out.extend(fmt_table(load("pod16x16")))
    multi = load("pod2x16x16")
    if multi:
        ok = sum(1 for d in multi if "memory" in d)
        skip = sum(1 for d in multi if "skipped" in d)
        err = sum(1 for d in multi if "error" in d)
        out.append("")
        out.append(f"# multi-pod 2x16x16 pass: {ok} compiled, {skip} skipped, "
                   f"{err} errors")
    return out


if __name__ == "__main__":
    print("\n".join(run()))
