"""E5: the paper's end-to-end scenario — a BERT-tiny-class transformer's
GEMMs on the CGRA, per-layer latency/energy/power budget (int8), plus the
blocked-vs-naive and fp32-vs-int8 deltas the paper argues for."""
from repro.configs import get_config
from repro.core.cgra import CGRAConfig, simulate_transformer_layer


def run() -> list[str]:
    cfg = get_config("cgra-edge")
    cgra = CGRAConfig()
    out = ["# E5 edge transformer on the CGRA (cgra-edge: 4L d=256 4H ff=1024)"]
    out.append("variant,layer_us,layer_uJ,power_mW,pe_util,tokens_per_s(4L,seq128)")
    for name, c, dt, blocked in (
        ("int8_blocked", cgra, "int8", True),
        ("int8_naive", cgra, "int8", False),
        ("fp32_blocked", cgra, "fp32", True),
        ("switched_noc_int8", CGRAConfig(switched_noc=True), "int8", True),
    ):
        tot, _ = simulate_transformer_layer(c, cfg.d_model, cfg.num_heads,
                                            cfg.head_dim, cfg.d_ff, seq=128,
                                            dtype=dt, blocked=blocked)
        tps = 128 / (4 * tot.time_us / 1e6)
        out.append(f"{name},{tot.time_us:.0f},{tot.energy_pj/1e6:.1f},"
                   f"{tot.power_mw:.3f},{tot.pe_utilization:.2f},{tps:.1f}")
    out.append("derived: int8+blocking is the paper's operating point — "
               "mW-class power at full PE utilization; naive dataflow loses "
               "~4.5x cycles, fp32 loses the packing factor")
    return out


if __name__ == "__main__":
    print("\n".join(run()))
