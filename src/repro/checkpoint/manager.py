"""Fault-tolerant checkpointing: atomic, keep-N, async, reshard-on-load.

Layout (per checkpoint):
    <dir>/step_<n>.tmp/...   -> atomic rename to <dir>/step_<n>/
        meta.json            (step, arch name, mesh shape, tree structure)
        arrays.npz           (flattened leaves, keyed by tree path)

Arrays are written logically-full (gathered); restore re-shards onto
whatever mesh/sharding the caller provides — this is the elastic-scaling
path (save at dp=4, restore at dp=2 is tested).  On a real multi-host pod
the same layout splits arrays.npz into per-host shard files; the index in
meta.json already records per-leaf shapes to support that.
"""
from __future__ import annotations

import json
import os
import re
import shutil
import threading
import time
from typing import Any

import numpy as np

import jax
import jax.numpy as jnp


def _flatten(tree) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        flat[key] = np.asarray(leaf)
    return flat


class CheckpointManager:
    def __init__(self, directory: str, keep_n: int = 3, async_save: bool = True):
        self.dir = directory
        self.keep_n = keep_n
        self.async_save = async_save
        self._thread: threading.Thread | None = None
        os.makedirs(directory, exist_ok=True)

    # ------------------------------------------------------------- save
    def save(self, step: int, tree: Any, extra_meta: dict | None = None):
        self.wait()  # one in-flight save at a time
        flat = _flatten(jax.tree.map(lambda x: np.asarray(x), tree))
        meta = {"step": int(step), "time": time.time(), **(extra_meta or {})}

        def _write():
            tmp = os.path.join(self.dir, f"step_{step}.tmp")
            final = os.path.join(self.dir, f"step_{step}")
            os.makedirs(tmp, exist_ok=True)
            np.savez(os.path.join(tmp, "arrays.npz"), **flat)
            with open(os.path.join(tmp, "meta.json"), "w") as f:
                json.dump(meta, f)
            if os.path.exists(final):
                shutil.rmtree(final)
            os.rename(tmp, final)  # atomic commit
            self._gc()

        if self.async_save:
            self._thread = threading.Thread(target=_write, daemon=True)
            self._thread.start()
        else:
            _write()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self):
        steps = sorted(self.all_steps())
        for s in steps[: max(0, len(steps) - self.keep_n)]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s}"), ignore_errors=True)

    # ------------------------------------------------------------- load
    def all_steps(self) -> list[int]:
        out = []
        for name in os.listdir(self.dir):
            m = re.fullmatch(r"step_(\d+)", name)
            if m and os.path.exists(os.path.join(self.dir, name, "meta.json")):
                out.append(int(m.group(1)))
        return sorted(out)

    def latest_step(self) -> int | None:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: int, like: Any, shardings: Any = None) -> Any:
        """Restore into the structure of `like` (a pytree of arrays or
        ShapeDtypeStructs).  `shardings` (matching pytree or single sharding)
        re-shards every leaf — the elastic path."""
        path = os.path.join(self.dir, f"step_{step}")
        with np.load(os.path.join(path, "arrays.npz")) as z:
            data = {k: z[k] for k in z.files}
        leaves_like, treedef = jax.tree_util.tree_flatten_with_path(like)
        single = isinstance(shardings, jax.sharding.Sharding)
        shard_leaves = (jax.tree.leaves(shardings)
                        if shardings is not None and not single else None)
        out = []
        for i, (pth, leaf) in enumerate(leaves_like):
            key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in pth)
            arr = data[key]
            if tuple(arr.shape) != tuple(leaf.shape):
                raise ValueError(f"shape mismatch for {key}: {arr.shape} vs {leaf.shape}")
            arr = arr.astype(leaf.dtype)
            if shardings is not None:
                sh = shard_leaves[i] if shard_leaves is not None else shardings
                out.append(jax.device_put(arr, sh))
            else:
                out.append(jnp.asarray(arr))
        return jax.tree_util.tree_unflatten(treedef, out)

    def meta(self, step: int) -> dict:
        with open(os.path.join(self.dir, f"step_{step}", "meta.json")) as f:
            return json.load(f)
