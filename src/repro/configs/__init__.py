"""Config registry: ``get_config(name)`` + ``reduce_config`` for smoke tests."""
from __future__ import annotations

import jax.numpy as jnp

from repro.configs.base import (
    ArchConfig,
    LayerSpec,
    ShapeConfig,
    SHAPES,
    Stage,
    build_stages,
    cell_skip_reason,
)

from repro.configs import (
    cgra_edge,
    deepseek_67b,
    gemma3_4b,
    hubert_xlarge,
    jamba_v01_52b,
    kimi_k2_1t_a32b,
    llama32_vision_11b,
    mamba2_130m,
    minicpm3_4b,
    olmo_1b,
    qwen3_moe_30b_a3b,
)

REGISTRY: dict[str, ArchConfig] = {
    m.CONFIG.name: m.CONFIG
    for m in (
        gemma3_4b,
        minicpm3_4b,
        olmo_1b,
        deepseek_67b,
        jamba_v01_52b,
        kimi_k2_1t_a32b,
        qwen3_moe_30b_a3b,
        mamba2_130m,
        llama32_vision_11b,
        hubert_xlarge,
        cgra_edge,
    )
}

ASSIGNED = [n for n in REGISTRY if n != "cgra-edge"]


def get_config(name: str) -> ArchConfig:
    if name not in REGISTRY:
        raise KeyError(f"unknown arch {name!r}; available: {sorted(REGISTRY)}")
    return REGISTRY[name]


def reduce_config(cfg: ArchConfig) -> ArchConfig:
    """Shrink an arch to smoke-test size while preserving its structural family
    (layer pattern, MoE/MLA/SSD/hybrid/cross-attn wiring all still exercised)."""
    # keep just enough layers to cover one full pattern period (+1 to exercise
    # the scan) for heterogeneous stacks
    if cfg.ssm_every:
        layers = cfg.ssm_every
    elif cfg.cross_every:
        layers = cfg.cross_every
    elif cfg.local_global_pattern:
        layers = cfg.local_global_pattern + 1
    elif cfg.num_experts and cfg.moe_every > 1:
        layers = cfg.moe_every * 2
    else:
        layers = 2
    kw = dict(
        num_layers=layers,
        d_model=64,
        d_ff=128 if cfg.d_ff else 0,
        vocab_size=256,
        param_dtype=jnp.float32,
        compute_dtype=jnp.float32,
        pad_heads_to=1,
        pad_vocab_to=32,
        fsdp=False,
        remat_policy="none",
    )
    if cfg.num_heads:
        kw.update(num_heads=4, head_dim=16)
        kw.update(num_kv_heads=2 if cfg.num_kv_heads < cfg.num_heads else 4)
    if cfg.use_mla:
        kw.update(q_lora_rank=32, kv_lora_rank=16, qk_nope_dim=8, qk_rope_dim=8,
                  v_head_dim=16, head_dim=16)
    if cfg.num_experts:
        kw.update(num_experts=4, experts_per_token=2, moe_d_ff=32)
    if cfg.ssm_state:
        kw.update(ssm_state=16, ssm_headdim=16, ssm_chunk=32)
    if cfg.window_size:
        kw.update(window_size=32)
    if cfg.vision_tokens:
        kw.update(vision_tokens=16, vision_dim=32)
    if cfg.frontend_dim:
        kw.update(frontend_dim=64)
    return cfg.with_(**kw).with_(name=cfg.name + "-smoke")


__all__ = [
    "ArchConfig", "LayerSpec", "Stage", "ShapeConfig", "SHAPES",
    "build_stages", "cell_skip_reason", "REGISTRY", "ASSIGNED",
    "get_config", "reduce_config",
]
