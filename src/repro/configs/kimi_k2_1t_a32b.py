"""kimi-k2-1t-a32b — trillion-parameter MoE (384 experts, top-8).

[arXiv:2501.kimi2; unverified, paper-table] 61L d_model=7168 64H (GQA kv=8)
expert d_ff=2048 vocab=163840, MoE 384e top-8.  We follow the assignment table
exactly (GQA kv=8, every layer MoE) rather than undisclosed HF details.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="kimi-k2-1t-a32b",
    family="moe",
    num_layers=61,
    d_model=7168,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=2048,
    vocab_size=163_840,
    num_experts=384,
    experts_per_token=8,
    moe_d_ff=2048,
    moe_every=1,
)
