"""Architecture configuration system.

Every assigned architecture is described by an :class:`ArchConfig`.  The config
is a *complete* description: the model zoo in ``repro.models`` builds parameter
trees and apply functions purely from it, the launcher derives shardings from
it, and the dry-run derives input specs from it.

Layer stacks are expressed as a repeating *pattern* of :class:`LayerSpec`s
(mixer kind + ffn kind).  ``build_stages`` factors the pattern into scan-able
stages (a group of layers scanned ``repeats`` times) so that 95-layer models
compile as a single small HLO while heterogeneous interleaves (Jamba 1:7,
Gemma-3 5:1) remain exact.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any, Sequence

import jax.numpy as jnp

# ---------------------------------------------------------------------------
# Layer pattern machinery
# ---------------------------------------------------------------------------

# mixer kinds: attn_global | attn_local | ssm | cross  (cross = self-attn layer
# followed by an image cross-attention sub-block, Llama-3.2-Vision style)
# ffn kinds:   dense | moe
@dataclass(frozen=True)
class LayerSpec:
    mixer: str
    ffn: str


@dataclass(frozen=True)
class Stage:
    """``repeats`` scanned iterations of a fixed ``group`` of layers."""

    group: tuple[LayerSpec, ...]
    repeats: int

    @property
    def num_layers(self) -> int:
        return len(self.group) * self.repeats


def _is_periodic(specs: Sequence[LayerSpec], p: int) -> bool:
    return all(specs[i] == specs[i % p] for i in range(len(specs)))


def build_stages(specs: Sequence[LayerSpec]) -> list[Stage]:
    """Factor a layer list into <=2 scan stages (main periodic prefix + tail)."""
    n = len(specs)
    if n == 0:
        return []
    for p in range(1, n + 1):
        n_full = n // p
        if n_full == 0:
            break
        prefix = specs[: n_full * p]
        if _is_periodic(prefix, p) and n_full * p >= max(p, n // 2):
            stages = [Stage(tuple(specs[:p]), n_full)]
            tail = specs[n_full * p :]
            if tail:
                stages.extend(build_stages(tail))
            return stages
    return [Stage(tuple(specs), 1)]


# ---------------------------------------------------------------------------
# Architecture config
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | vlm | audio
    kind: str = "decoder"  # decoder | encoder

    # core transformer dims
    num_layers: int = 0
    d_model: int = 0
    num_heads: int = 0
    num_kv_heads: int = 0
    head_dim: int = 0
    d_ff: int = 0
    vocab_size: int = 0

    # attention pattern
    local_global_pattern: int = 0  # N locals per global; 0 = all global
    window_size: int = 0  # sliding window for local layers
    rope_theta: float = 10_000.0
    logit_softcap: float = 0.0
    use_qk_norm: bool = False

    # MLA (multi-head latent attention)
    use_mla: bool = False
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_nope_dim: int = 0
    qk_rope_dim: int = 0
    v_head_dim: int = 0

    # MoE
    num_experts: int = 0
    experts_per_token: int = 0
    moe_d_ff: int = 0
    moe_every: int = 1  # apply MoE FFN on every k-th layer (1 = all layers)
    capacity_factor: float = 1.0
    num_moe_groups: int = 1  # dispatch groups (= DP shards at scale)
    # expert-sharded dispatch under manual shard_map: the right layout on TPU
    # (slot buffers shard over the model axis), but the CPU XLA backend
    # check-fails promoting the copy-combiner all-reduce its partitioner
    # emits for auto-axis contractions inside manual regions -> default off
    # in this container; flip on for real TPU runs.
    moe_shard_map: bool = False

    # SSM (Mamba-2 / SSD)
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_headdim: int = 64
    ssm_chunk: int = 256
    ssm_conv_width: int = 4
    ssm_every: int = 0  # hybrid: 1 attn per `ssm_every` layers (Jamba = 8); 0 = pure

    # VLM cross-attention
    cross_every: int = 0  # every k-th layer has an image cross-attn sub-block
    vision_tokens: int = 0
    vision_dim: int = 0

    # audio frontend stub
    audio_frontend: bool = False
    frontend_dim: int = 0

    # norm / misc
    norm_type: str = "rmsnorm"  # rmsnorm | layernorm_nonparam
    tie_embeddings: bool = False

    # dtypes
    param_dtype: Any = jnp.bfloat16
    compute_dtype: Any = jnp.bfloat16

    # execution knobs (overridable by launcher / perf loop)
    kernel_mode: str = "reference"  # reference | pallas | interpret
    # w8a8: weights int8-quantized once at load (models.model.quantize_params),
    # activations quantized per-row on the fly, GEMMs through the packed int8
    # kernel with fused dequant — the paper's packed-data edge-inference mode
    quant: str = "none"  # none | w8a8
    remat_policy: str = "full"  # none | dots | full
    pad_heads_to: int = 1  # pad q heads to a multiple of this (TP divisibility)
    pad_vocab_to: int = 256
    fsdp: bool = True  # shard params/opt over the data axis
    parallel_mode: str = "2d"  # "2d" (TP x FSDP) | "fsdp" (ZeRO-3 only)
    use_torus_tp: bool = False  # ring-collective tensor parallelism (paper mode)
    scan_layers: bool = True

    # ---------------- derived helpers ----------------

    @property
    def padded_vocab(self) -> int:
        pv = self.pad_vocab_to
        return ((self.vocab_size + pv - 1) // pv) * pv

    @property
    def padded_heads(self) -> int:
        ph = self.pad_heads_to
        return ((self.num_heads + ph - 1) // ph) * ph

    @property
    def d_inner(self) -> int:  # SSD inner width
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_headdim

    def layer_specs(self) -> list[LayerSpec]:
        specs = []
        for i in range(self.num_layers):
            # mixer
            if self.family in ("ssm",):
                mixer = "ssm"
            elif self.ssm_every:  # hybrid: one attn per ssm_every layers
                mixer = "attn_global" if (i % self.ssm_every) == self.ssm_every // 2 else "ssm"
            elif self.cross_every and ((i + 1) % self.cross_every == 0):
                mixer = "cross"
            elif self.local_global_pattern:
                p = self.local_global_pattern + 1
                mixer = "attn_global" if (i % p) == self.local_global_pattern else "attn_local"
            else:
                mixer = "attn_global"
            # ffn
            if self.num_experts and (i % self.moe_every == self.moe_every - 1):
                ffn = "moe"
            elif self.family == "ssm":
                ffn = "none"  # Mamba-2 blocks have no separate FFN
            else:
                ffn = "dense"
            specs.append(LayerSpec(mixer, ffn))
        return specs

    def stages(self, main_repeats: int | None = None) -> list[Stage]:
        """Scan stages; optionally override the main (largest) stage's repeats.

        ``main_repeats`` powers the roofline depth-extrapolation: compile at 1
        and 2 repeats of the main stage and extrapolate linearly — exact,
        because scan stages are homogeneous by construction.
        """
        stages = build_stages(self.layer_specs())
        if main_repeats is not None and stages:
            main = max(range(len(stages)), key=lambda i: stages[i].repeats)
            stages = [
                dataclasses.replace(s, repeats=main_repeats) if i == main else s
                for i, s in enumerate(stages)
            ]
        return stages

    def with_(self, **kw) -> "ArchConfig":
        return dataclasses.replace(self, **kw)


# ---------------------------------------------------------------------------
# Input shapes (the assigned shape set)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    step: str  # train | prefill | decode


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


def cell_skip_reason(cfg: ArchConfig, shape: ShapeConfig) -> str | None:
    """Return a reason string if this (arch x shape) cell is skipped, else None."""
    if cfg.kind == "encoder" and shape.step == "decode":
        return "encoder-only architecture has no autoregressive decode step"
    if shape.name == "long_500k":
        sub_quadratic = (
            cfg.family in ("ssm", "hybrid") or cfg.local_global_pattern > 0
        )
        if not sub_quadratic:
            return "pure full-attention arch: 524k dense-KV decode excluded per spec"
    return None
