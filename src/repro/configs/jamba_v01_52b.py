"""jamba-v0.1-52b — hybrid Mamba + attention (1:7) with MoE every other layer.

[arXiv:2403.19887; hf] 32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=65536,
MoE 16 experts top-2.  The SSM block uses the Mamba-2 SSD formulation (Jamba
v0.1 shipped Mamba-1); SSD re-expresses the recurrence as block GEMMs which is
the paper's blocking insight applied to SSMs — see DESIGN.md §4.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="jamba-v0.1-52b",
    family="hybrid",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=65_536,
    num_experts=16,
    experts_per_token=2,
    moe_d_ff=14336,
    moe_every=2,
    ssm_every=8,
    ssm_state=64,
    ssm_expand=2,
    ssm_headdim=64,
)
