"""minicpm3-4b — dense with Multi-head Latent Attention (MLA).

[hf:openbmb/MiniCPM3-4B; hf] 62L d_model=2560 40H d_ff=6400 vocab=73448.
MLA ranks follow the HF config: q_lora_rank=768, kv_lora_rank=256,
qk_nope_head_dim=64, qk_rope_head_dim=32, v_head_dim=64.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="minicpm3-4b",
    family="dense",
    num_layers=62,
    d_model=2560,
    num_heads=40,
    num_kv_heads=40,
    head_dim=96,  # qk_nope + qk_rope
    d_ff=6400,
    vocab_size=73_448,
    use_mla=True,
    q_lora_rank=768,
    kv_lora_rank=256,
    qk_nope_dim=64,
    qk_rope_dim=32,
    v_head_dim=64,
)
