"""llama-3.2-vision-11b — decoder with cross-attention image layers.

[hf:meta-llama/Llama-3.2-11B-Vision; unverified] 40L d_model=4096 32H
(GQA kv=8) d_ff=14336 vocab=128256; every 5th layer carries an image
cross-attention sub-block.  The vision frontend is a STUB per the assignment:
``input_specs()`` provides precomputed patch embeddings (1601 tokens of
dim 1280, ViT-H/14 @ 560px convention) which the backbone projects to
d_model.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="llama-3.2-vision-11b",
    family="vlm",
    num_layers=40,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=128_256,
    cross_every=5,
    vision_tokens=1601,
    vision_dim=1280,
)
