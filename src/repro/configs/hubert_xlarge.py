"""hubert-xlarge — encoder-only audio transformer (wav2vec2 arch).

[arXiv:2106.07447; unverified] 48L d_model=1280 16H (kv=16) d_ff=5120
vocab=504 (masked-prediction target codebook).  The CNN waveform frontend is a
STUB per the assignment: ``input_specs()`` provides precomputed frame
embeddings (B, T, 1280).  No autoregressive decode (encoder-only) — decode
shape cells are skipped.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="hubert-xlarge",
    family="audio",
    kind="encoder",
    num_layers=48,
    d_model=1280,
    num_heads=16,
    num_kv_heads=16,
    head_dim=80,
    d_ff=5120,
    vocab_size=504,
    norm_type="layernorm",
    audio_frontend=True,
    frontend_dim=1280,
)
