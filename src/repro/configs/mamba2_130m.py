"""mamba2-130m — attention-free SSM with state-space duality (SSD).

[arXiv:2405.21060; unverified] 24L d_model=768 vocab=50280 ssm_state=128.
d_inner = 2 x 768 = 1536, headdim 64 -> 24 SSD heads.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="mamba2-130m",
    family="ssm",
    num_layers=24,
    d_model=768,
    num_heads=0,
    num_kv_heads=0,
    head_dim=0,
    d_ff=0,
    vocab_size=50_280,
    ssm_state=128,
    ssm_expand=2,
    ssm_headdim=64,
)
