"""gemma3-4b — dense, 5:1 local:global sliding-window GQA.

[hf:google/gemma-3-1b-pt; unverified] 34L d_model=2560 8H (GQA kv=4)
d_ff=10240 vocab=262144, 5 local (window 1024) per 1 global layer.
head_dim follows the Gemma-3 convention of 256 (8 x 256 = 2048, o-proj back
to d_model).
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="gemma3-4b",
    family="dense",
    num_layers=34,
    d_model=2560,
    num_heads=8,
    num_kv_heads=4,
    head_dim=256,
    d_ff=10240,
    vocab_size=262_144,
    local_global_pattern=5,
    window_size=1024,
    rope_theta=1_000_000.0,
    use_qk_norm=True,
    tie_embeddings=True,
)
