"""cgra-edge — the paper's own deployment target: a tiny transformer whose
GEMMs run through the CGRA block-GEMM path (int8, 4x4 PE array, 4x2 MOBs).

The paper gives no concrete model; this is a representative edge transformer
(BERT-tiny class) used by ``examples/edge_inference.py`` and the CGRA
simulator benchmarks.
"""
import jax.numpy as jnp

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="cgra-edge",
    family="dense",
    num_layers=4,
    d_model=256,
    num_heads=4,
    num_kv_heads=4,
    head_dim=64,
    d_ff=1024,
    vocab_size=30_522,
    param_dtype=jnp.float32,
    compute_dtype=jnp.float32,
    fsdp=False,
)
