"""Fault tolerance & elasticity runtime.

Production posture for 1000+ nodes (see DESIGN.md §7), with every code path
exercisable on this single-host container:

- ``TrainRunner``: checkpoint-every-N, auto-resume-from-latest, per-step
  wall-time EWMA straggler monitor, failure capture -> restart-from-
  checkpoint (tested via injected failures in tests/test_runtime.py).
- Elasticity: because checkpoints store logical arrays and the data pipeline
  is a pure function of (seed, step), a restore onto a *different* mesh/DP
  degree resumes the exact token stream (tested: save at dp=4, restore dp=2).
- On a real pod the same hooks wire to health RPCs: `on_step` -> heartbeat,
  `StragglerMonitor.flag` -> replica eviction + elastic re-mesh.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable

import numpy as np


from repro.checkpoint.manager import CheckpointManager
from repro.serving.chaos import ChaosInjector


@dataclass
class StragglerMonitor:
    """EWMA step-time monitor: flags steps slower than `threshold` x EWMA.
    At pod scale the flagged replica is evicted and the mesh rebuilt; here
    the flag is surfaced to the runner (and tested with injected delays)."""
    alpha: float = 0.1
    threshold: float = 3.0
    warmup: int = 3
    ewma: float = 0.0
    count: int = 0
    flagged: list = field(default_factory=list)

    def observe(self, step: int, dt: float) -> bool:
        self.count += 1
        if self.count <= self.warmup:
            self.ewma = dt if self.ewma == 0 else 0.5 * (self.ewma + dt)
            return False
        slow = dt > self.threshold * self.ewma
        if slow:
            self.flagged.append((step, dt, self.ewma))
        self.ewma = (1 - self.alpha) * self.ewma + self.alpha * dt
        return slow


class FailureInjector(ChaosInjector):
    """Deterministic failure injection for tests: raises at given steps.

    A thin specialization of the serving chaos harness
    (:class:`repro.serving.chaos.ChaosInjector`) over a single
    ``train.step`` fault point keyed by the external step number — each
    step fires at most once, so a restarted run re-traversing the same
    steps does not re-fail."""

    def __init__(self, fail_at: set[int] | None = None):
        super().__init__(schedule={"train.step": set(fail_at or ())},
                         points=("train.step",))
        self.fail_at = set(fail_at or ())
        self.fired: set[int] = set()

    def maybe_fail(self, step: int):
        if step in self.fail_at and step not in self.fired:
            self.fired.add(step)
            self.events.append(("train.step", step))
            raise RuntimeError(f"injected node failure at step {step}")


@dataclass
class RunReport:
    steps_run: int = 0
    restarts: int = 0
    final_step: int = 0
    losses: list = field(default_factory=list)
    straggler_flags: int = 0


class TrainRunner:
    """Checkpointed training loop with automatic restart-from-latest.

    `train_step(state, batch) -> (state, metrics)` and `batch_fn(step)` are
    pure; all restart state lives in the checkpoint + step index.
    """

    def __init__(self, train_step: Callable, batch_fn: Callable,
                 ckpt: CheckpointManager, *, ckpt_every: int = 10,
                 monitor: StragglerMonitor | None = None,
                 injector: FailureInjector | None = None,
                 max_restarts: int = 3):
        self.train_step = train_step
        self.batch_fn = batch_fn
        self.ckpt = ckpt
        self.ckpt_every = ckpt_every
        self.monitor = monitor or StragglerMonitor()
        self.injector = injector
        self.max_restarts = max_restarts

    def _resume(self, init_state):
        latest = self.ckpt.latest_step()
        if latest is None:
            return init_state, 0
        state = self.ckpt.restore(latest, init_state)
        return state, latest

    def run(self, init_state, total_steps: int) -> tuple[Any, RunReport]:
        report = RunReport()
        restarts = 0
        while True:
            state, start = self._resume(init_state)
            try:
                for step in range(start, total_steps):
                    if self.injector is not None:
                        self.injector.maybe_fail(step)
                    t0 = time.time()
                    state, metrics = self.train_step(state, self.batch_fn(step))
                    loss = metrics.get("loss")
                    if loss is not None:
                        loss = float(loss)
                        if not np.isfinite(loss):
                            raise FloatingPointError(f"non-finite loss at {step}")
                        report.losses.append(loss)
                    if self.monitor.observe(step, time.time() - t0):
                        report.straggler_flags += 1
                    report.steps_run += 1
                    if (step + 1) % self.ckpt_every == 0 or step + 1 == total_steps:
                        self.ckpt.save(step + 1, state)
                self.ckpt.wait()
                report.restarts = restarts
                report.final_step = total_steps
                return state, report
            except (RuntimeError, FloatingPointError) as e:
                restarts += 1
                if restarts > self.max_restarts:
                    raise
                self.ckpt.wait()  # make sure the last save committed
