from repro.runtime.ft import FailureInjector, RunReport, StragglerMonitor, TrainRunner  # noqa: F401
