"""Public serving configuration surface: ``EngineConfig`` + ``CacheSpec``.

``EngineConfig`` is the one frozen object that fully determines an engine's
compiled shapes and memory: previous PRs accreted these as loose ``Engine``
kwargs (``max_slots=``, ``prefill_bucket=``, ``kernel_mode=``, ...); the old
spelling still works through a ``DeprecationWarning`` shim in ``Engine``.

``CacheSpec`` describes the engine's KV-cache geometry (layout, page size,
pool size) and is derived from the config via ``EngineConfig.cache_spec()``.
"""
from __future__ import annotations

from dataclasses import dataclass

from repro.core import round_up
from repro.core.cache import CacheLayout


@dataclass(frozen=True)
class CacheSpec:
    """Geometry of a serving KV cache.

    ``layout=PAGED``: ``n_pages`` pages of ``page_size`` rows each (page 0 is
    the engine's reserved trash page), page tables of width
    ``pages_per_seq`` rows.  ``max_rows`` is the usable KV row budget —
    the number every fixed-slot-vs-paged capacity comparison is made at.
    """
    layout: CacheLayout = CacheLayout.PAGED
    page_size: int = 64
    n_pages: int = 0
    max_len: int = 512

    @property
    def pages_per_seq(self) -> int:
        return -(-self.max_len // self.page_size)

    @property
    def max_rows(self) -> int:
        """Usable KV rows (the trash page is bookkeeping, not capacity)."""
        return (self.n_pages - 1) * self.page_size


@dataclass(frozen=True)
class MeshSpec:
    """Serving mesh geometry: ``data`` replicas × ``model`` tensor/expert-
    parallel shards, built over the first ``data * model`` jax devices (a
    submesh — the platform may have more; see
    ``launch.mesh.make_device_mesh``).  Parse the CLI spelling with
    ``MeshSpec.parse("2x4")`` (``"4"`` alone means model-parallel only)."""
    data: int = 1
    model: int = 1

    def __post_init__(self):
        if self.data < 1 or self.model < 1:
            raise ValueError(f"mesh axes must be >= 1, got "
                             f"data={self.data} model={self.model}")

    @property
    def size(self) -> int:
        return self.data * self.model

    @classmethod
    def parse(cls, s: "str | MeshSpec") -> "MeshSpec":
        if isinstance(s, MeshSpec):
            return s
        parts = str(s).lower().replace("×", "x").split("x")
        try:
            if len(parts) == 1:
                return cls(1, int(parts[0]))
            if len(parts) == 2:
                return cls(int(parts[0]), int(parts[1]))
        except ValueError:
            pass
        raise ValueError(f"mesh spec {s!r}: expected 'DxM' (e.g. '1x8') or "
                         f"a bare model-parallel degree (e.g. '8')")

    def build(self):
        """The jax Mesh (imports jax; config construction itself does not)."""
        from repro.launch.mesh import make_device_mesh
        return make_device_mesh((self.data, self.model), ("data", "model"))


@dataclass(frozen=True)
class EngineConfig:
    """Everything the serving engine compiles and allocates against.

    page_size:     KV rows per page (multiple of 8 — TPU sublane alignment)
    n_pages:       page-pool size, incl. the reserved trash page; ``None``
                   derives ``max_batch * ceil(max_len / page_size) + 1`` (the
                   fixed-slot-equivalent budget, so legacy configs keep their
                   old capacity)
    max_batch:     concurrent sequences (the decode batch dimension)
    max_len:       per-sequence row cap; admission requires
                   ``len(prompt) + max_new <= max_len`` (exact — paging has
                   no pad rows to budget for)
    prefix_cache:  share KV pages between requests with a common prompt
                   prefix (radix tree + refcounted copy-on-write); auto-
                   disabled for architectures with SSM/cross-attention
                   mixers, whose prefill is not prefix-decomposable
    decode_chunk:  scan steps per compiled decode call
    chunk_tokens:  chunked-prefill budget — at most this many prompt tokens
                   per engine tick, run *together with* one decode step per
                   in-flight sequence in a single compiled mixed step, so
                   long prompts stream through without stalling decodes.
                   ``None`` (default) prefills each prompt in one
                   whole-suffix chunk (still through the mixed step on
                   prefix-decomposable models — one compiled variant per
                   power-of-two bucket, not per prompt length)
    slo_ttft_s:    optional time-to-first-token SLO budget (seconds) — pure
                   metadata for goodput reporting, no scheduling effect
    slo_itl_s:     optional inter-token-latency SLO budget (seconds), ditto
    eos_id:        optional stop token (checked inside the scan)
    max_queue:     admission-control queue bound; past it ``submit`` finishes
                   the request immediately as ``FinishReason.REJECTED`` with
                   a ``retry_after_s`` backpressure hint (never a silent
                   drop, never an unbounded queue)
    deadline_s:    default per-request deadline (seconds from submission,
                   spanning queueing and execution); requests past it retire
                   ``FinishReason.DEADLINE``.  ``None`` (default) means no
                   deadline; ``submit(deadline_s=...)`` overrides per request
    preemption:    page-pressure policy.  ``"off"`` (default): admission
                   reserves each request's full page need up front and the
                   pool can never exhaust mid-decode.  ``"recompute"``:
                   admission reserves only the prompt's pages, decode rows
                   grow lazily, and on exhaustion the scheduler preempts the
                   lowest-priority decoding slot (fewest tokens generated,
                   ties by latest arrival), frees its pages and requeues it —
                   its generated tokens recompute via normal chunked prefill
                   on re-admission, greedy outputs bit-identical to the
                   never-preempted run.  ``"drop"``: same victim policy, but
                   the victim retires ``FinishReason.PREEMPTED`` with its
                   partial output (load shedding)
    kernel_mode:   override ``cfg.kernel_mode`` (reference|interpret|pallas)
    quant:         override ``cfg.quant`` ("w8a8" quantizes weights at init)
    mesh:          optional ``MeshSpec`` — place params/caches with
                   ``NamedSharding`` over a ``(data, model)`` device mesh and
                   compile every executable under it (tensor-parallel dense
                   layers, KV pools sharded over KV heads, expert-parallel
                   MoE).  ``None`` (default) keeps the single-device path
                   byte-for-byte unchanged.  Accepts a ``MeshSpec`` or the
                   CLI string spelling (``"1x8"``/``"8"``)
    """
    page_size: int = 64
    n_pages: int | None = None
    max_batch: int = 8
    max_len: int = 512
    prefix_cache: bool = True
    decode_chunk: int = 8
    chunk_tokens: int | None = None
    slo_ttft_s: float | None = None
    slo_itl_s: float | None = None
    eos_id: int | None = None
    max_queue: int = 1024
    deadline_s: float | None = None
    preemption: str = "off"
    kernel_mode: str | None = None
    quant: str | None = None
    mesh: MeshSpec | str | None = None

    def __post_init__(self):
        if self.page_size < 8 or self.page_size % 8:
            raise ValueError(f"page_size={self.page_size} must be a positive "
                             f"multiple of 8 (TPU sublane alignment)")
        if self.chunk_tokens is not None and self.chunk_tokens < 1:
            raise ValueError(f"chunk_tokens={self.chunk_tokens} must be >= 1 "
                             f"(or None for whole-suffix prefill)")
        if self.preemption not in ("off", "recompute", "drop"):
            raise ValueError(f"preemption={self.preemption!r} must be one of "
                             f"'off', 'recompute', 'drop'")
        if self.deadline_s is not None and self.deadline_s <= 0:
            raise ValueError(f"deadline_s={self.deadline_s} must be > 0 "
                             f"(or None for no deadline)")
        if self.max_len % self.page_size:
            object.__setattr__(self, "max_len",
                               round_up(self.max_len, self.page_size))
        if self.n_pages is None:
            per_seq = self.max_len // self.page_size
            object.__setattr__(self, "n_pages",
                               self.max_batch * per_seq + 1)
        if self.n_pages < 2:
            raise ValueError("n_pages must be >= 2 (one usable page plus the "
                             "reserved trash page)")
        if self.mesh is not None and not isinstance(self.mesh, MeshSpec):
            object.__setattr__(self, "mesh", MeshSpec.parse(self.mesh))

    def cache_spec(self) -> CacheSpec:
        return CacheSpec(CacheLayout.PAGED, self.page_size, self.n_pages,
                         self.max_len)
