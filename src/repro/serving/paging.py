"""Host-side KV paging: page-pool allocator + radix prefix cache.

These classes own *indices only* — the device-side page pools (one
``[n_pages, page_size, ...]`` array per attention layer) live in the engine;
everything here is O(tokens) Python bookkeeping per request, off the hot
path.

``PagePool`` is a free-list allocator with refcounts: a page's count is the
number of sequence page-tables holding it plus one if the radix tree holds
it; it returns to the free list exactly when the count hits zero.  Page 0 is
reserved as the engine's *trash page* (retired batch rows keep writing
somewhere harmless), so it is never allocated and never freed.

``RadixCache`` is a trie over page-sized token chunks (SGLang-style): an
edge exists per cached full page, keyed by the exact ``page_size`` tokens
whose KV it holds.  A lookup returns the longest cached prefix as (a) whole
pages to share by reference (incref, zero copies) and (b) at most one
partially-matching page to share by *copy-on-write* — the new sequence gets
a fresh page, the matched rows are device-copied, and it diverges freely
while the donor page stays immutable under the tree.  Shared full pages are
never written by any holder (decode writes only at ``pos >= prompt_len``),
so reference-sharing needs no write barrier; the COW copy is the only
data-plane cost of divergence.
"""
from __future__ import annotations

from dataclasses import dataclass, field


class PagePool:
    """Refcounted free-list allocator over page ids ``1..n_pages-1``."""

    def __init__(self, n_pages: int):
        if n_pages < 2:
            raise ValueError("need at least one usable page + the trash page")
        self.n_pages = n_pages
        self._free = list(range(n_pages - 1, 0, -1))  # pop() yields 1, 2, ...
        self._rc = [0] * n_pages
        self._rc[0] = 1  # trash page: pinned forever
        #: optional fault hook (chaos harness point ``pool.alloc``): a
        #: zero-arg callable; when it returns True, ``alloc`` reports
        #: exhaustion even if a free page exists.  Callers already handle
        #: ``None`` (evict / requeue / preempt), so an injected failure
        #: exercises exactly the real exhaustion paths.
        self.fault = None

    @property
    def num_free(self) -> int:
        return len(self._free)

    @property
    def num_used(self) -> int:
        return (self.n_pages - 1) - len(self._free)

    def refcount(self, pid: int) -> int:
        return self._rc[pid]

    def alloc(self) -> int | None:
        """One page with refcount 1, or ``None`` when the pool is exhausted
        (callers evict from the radix cache and retry, or stay queued)."""
        if self.fault is not None and self.fault():
            return None
        if not self._free:
            return None
        pid = self._free.pop()
        assert self._rc[pid] == 0, f"page {pid} on free list with refs"
        self._rc[pid] = 1
        return pid

    def incref(self, pid: int):
        assert 0 < pid < self.n_pages and self._rc[pid] > 0, pid
        self._rc[pid] += 1

    def decref(self, pid: int):
        assert 0 < pid < self.n_pages and self._rc[pid] > 0, pid
        self._rc[pid] -= 1
        if self._rc[pid] == 0:
            self._free.append(pid)


@dataclass
class PrefixMatch:
    """Longest cached prefix of a prompt.

    ``full_pages`` are shared by reference (caller increfs each);
    ``partial`` is ``(donor_page, rows)`` for a copy-on-write share of the
    donor's first ``rows`` rows, or ``None``.  ``tokens`` is the total
    matched length: ``len(full_pages) * page_size + rows``.
    """
    full_pages: list[int] = field(default_factory=list)
    partial: tuple[int, int] | None = None
    tokens: int = 0


class _Node:
    __slots__ = ("children", "page", "parent", "chunk", "tick")

    def __init__(self, page: int = -1, parent: "_Node | None" = None,
                 chunk: tuple | None = None):
        self.children: dict[tuple, _Node] = {}
        self.page = page        # -1 only at the root
        self.parent = parent
        self.chunk = chunk      # edge key in parent.children
        self.tick = 0


class RadixCache:
    """Trie of cached full KV pages, keyed by their exact token chunks."""

    def __init__(self, page_size: int, pool: PagePool):
        self.page_size = page_size
        self.pool = pool
        self.root = _Node()
        self._tick = 0
        self.hit_tokens = 0      # matched prefix tokens across lookups
        self.lookup_tokens = 0   # total prompt tokens across lookups

    @property
    def hit_rate(self) -> float:
        return self.hit_tokens / self.lookup_tokens if self.lookup_tokens \
            else 0.0

    def _touch(self, node: _Node):
        self._tick += 1
        while node is not self.root:
            node.tick = self._tick
            node = node.parent

    # ------------------------------------------------------------------
    # lookup / insert
    # ------------------------------------------------------------------

    def match(self, tokens: list[int], max_match: int | None = None
              ) -> PrefixMatch:
        """Longest cached prefix of ``tokens``, capped at ``max_match``
        (callers cap at ``len(tokens) - 1`` so at least one token is left to
        prefill).  Accounts hit/lookup token counts."""
        ps = self.page_size
        cap = len(tokens) if max_match is None else min(max_match, len(tokens))
        m = PrefixMatch()
        node = self.root
        i = 0
        while i + ps <= cap:
            child = node.children.get(tuple(tokens[i: i + ps]))
            if child is None:
                break
            m.full_pages.append(child.page)
            node = child
            i += ps
        # partial: the child sharing the longest strict prefix of the tail
        tail = tokens[i: min(i + ps, cap)]
        best_r, best_page = 0, -1
        if tail:
            for chunk, child in node.children.items():
                r = 0
                for a, b in zip(chunk, tail):
                    if a != b:
                        break
                    r += 1
                if r > best_r:
                    best_r, best_page = r, child.page
        if best_r:
            m.partial = (best_page, best_r)
        m.tokens = i + best_r
        if node is not self.root:
            self._touch(node)
        self.hit_tokens += m.tokens
        self.lookup_tokens += len(tokens)
        return m

    def insert(self, tokens: list[int], pages: list[int]) -> int:
        """Register a prefilled prompt's *full* pages: ``pages[j]`` holds the
        KV of ``tokens[j*ps : (j+1)*ps]``.  New edges incref their page (the
        tree's reference); chunks already cached are left as-is (the tree
        keeps its original page — contents are identical by construction).
        Returns the number of pages newly inserted."""
        ps = self.page_size
        node, new = self.root, 0
        for j in range(len(tokens) // ps):
            chunk = tuple(tokens[j * ps: (j + 1) * ps])
            child = node.children.get(chunk)
            if child is None:
                if j >= len(pages):
                    break
                child = _Node(pages[j], node, chunk)
                node.children[chunk] = child
                self.pool.incref(pages[j])
                new += 1
            node = child
        if node is not self.root:
            self._touch(node)
        return new

    # ------------------------------------------------------------------
    # eviction
    # ------------------------------------------------------------------

    def _leaves(self):
        out, stack = [], [self.root]
        while stack:
            n = stack.pop()
            if n is not self.root and not n.children:
                out.append(n)
            stack.extend(n.children.values())
        return out

    def num_evictable(self) -> int:
        """Pages :meth:`evict` could free right now: nodes whose page only
        the tree holds (refcount 1) *and* whose whole subtree is likewise
        tree-only — eviction proceeds leaf-inward, so an inner node is
        unreachable while any descendant must stay.  Admission uses this to
        decide whether evicting can actually satisfy a request before
        giving up any cached pages."""

        def rec(node: _Node) -> tuple[int, bool]:
            total, subtree_ok = 0, True
            for child in node.children.values():
                cnt, ok = rec(child)
                total += cnt
                subtree_ok = subtree_ok and ok
            if node is self.root:
                return total, subtree_ok
            ok = subtree_ok and self.pool.refcount(node.page) == 1
            return total + (1 if ok else 0), ok

        return rec(self.root)[0]

    def evict(self, need_pages: int) -> int:
        """LRU-evict unreferenced leaves until the pool has ``need_pages``
        free (or nothing more is evictable).  A page is evictable iff only
        the tree holds it (refcount 1) and its node is a leaf — evicting a
        leaf may expose its parent for the next round.  Returns #evicted."""
        evicted = 0
        while self.pool.num_free < need_pages:
            cands = [n for n in self._leaves()
                     if self.pool.refcount(n.page) == 1]
            if not cands:
                break
            victim = min(cands, key=lambda n: n.tick)
            del victim.parent.children[victim.chunk]
            self.pool.decref(victim.page)
            evicted += 1
        return evicted

    def clear(self):
        """Drop every tree reference (tests / engine reset)."""
        stack = list(self.root.children.values())
        while stack:
            n = stack.pop()
            stack.extend(n.children.values())
            self.pool.decref(n.page)
        self.root.children.clear()


def check_invariants(pool: PagePool, radix: RadixCache | None = None,
                     tables=None) -> list[str]:
    """Structural invariants of the paging state; returns violations (empty
    list == healthy).  Reusable by tests, the engine, and the
    ``repro.analysis`` CLI (rule P001).

    ``tables`` — optional iterable of per-sequence page-id collections (the
    scheduler's ``owned`` lists / page tables).  When given, refcounts are
    reconciled exactly: ``rc[p] == #tables holding p + (1 if the radix tree
    holds p)``.  Without it only one-sided bounds are checked (the pool
    cannot know its external holders).  Call at quiescent points — mid-
    admission pin/unpin windows legitimately hold transient references.
    """
    bad: list[str] = []
    n = pool.n_pages
    free = list(pool._free)
    rc = list(pool._rc)

    # trash page 0: pinned forever, never allocatable
    if rc[0] < 1:
        bad.append(f"trash page 0 has refcount {rc[0]} (must stay pinned)")
    if 0 in free:
        bad.append("trash page 0 is on the free list")

    # free list: unique, in range, and exactly the rc == 0 pages
    if len(set(free)) != len(free):
        dup = sorted(p for p in set(free) if free.count(p) > 1)
        bad.append(f"free list holds duplicate pages {dup}")
    for p in free:
        if not (0 < p < n):
            bad.append(f"free list holds out-of-range page {p}")
        elif rc[p] != 0:
            bad.append(f"page {p} is free but has refcount {rc[p]}")
    for p in range(1, n):
        if rc[p] == 0 and p not in set(free):
            bad.append(f"page {p} has refcount 0 but is not on the free list")
        if rc[p] < 0:
            bad.append(f"page {p} has negative refcount {rc[p]}")

    # conservation
    if pool.num_free + pool.num_used != n - 1:
        bad.append(f"num_free ({pool.num_free}) + num_used ({pool.num_used})"
                   f" != usable pages ({n - 1})")

    tree_pages: list[int] = []
    if radix is not None:
        ps = radix.page_size
        stack = [(radix.root, None, None)]
        while stack:
            node, parent, key = stack.pop()
            if node is not radix.root:
                tree_pages.append(node.page)
                if not (0 < node.page < n):
                    bad.append(f"radix node holds out-of-range page"
                               f" {node.page}")
                elif rc[node.page] < 1:
                    bad.append(f"radix node holds page {node.page} with"
                               f" refcount {rc[node.page]}")
                if node.chunk is None or len(node.chunk) != ps:
                    bad.append(f"radix node for page {node.page} has chunk"
                               f" length {len(node.chunk or ())} != page_size")
                if node.parent is not parent or key != node.chunk:
                    bad.append(f"radix node for page {node.page} has"
                               f" inconsistent parent/edge links")
            for chunk, child in node.children.items():
                stack.append((child, node, chunk))
        if len(set(tree_pages)) != len(tree_pages):
            bad.append("radix tree holds the same page in two nodes")
        # evictable pages are a subset of tree-held rc == 1 pages
        ev = radix.num_evictable()
        cap = sum(1 for p in tree_pages if rc[p] == 1)
        if ev > cap:
            bad.append(f"num_evictable ({ev}) exceeds tree-only pages ({cap})")

    if tables is not None:
        held: dict[int, int] = {}
        for t in tables:
            for p in t:
                p = int(p)
                if p != 0:
                    held[p] = held.get(p, 0) + 1
        for p in set(tree_pages):
            held[p] = held.get(p, 0) + 1
        for p in range(1, n):
            want = held.get(p, 0)
            if rc[p] != want:
                bad.append(f"page {p} refcount {rc[p]} != {want} references"
                           f" (tables + radix tree)")
    return bad
