"""Deterministic chaos injection for the serving engine.

:class:`ChaosInjector` generalizes the training path's
``runtime.ft.FailureInjector`` into a catalog of *named fault points* that
the serving stack consults at well-defined seams:

``pool.alloc``
    ``PagePool.alloc`` returns ``None`` (transient exhaustion) even though a
    free page exists.  Exercises admission rollback and, with
    ``EngineConfig(preemption=...)``, the preempt/recompute path.
``runner.mixed``
    The engine's compiled tick (mixed step or decode chunk) fails *before
    dispatch* — no device state has been mutated, so the tick is simply
    skipped and retried.  Raised as :class:`ChaosError` and absorbed by
    ``Engine.step``.
``logits.nan``
    One live slot's logits are poisoned to NaN inside the compiled step
    (via the runner's ``nanmask`` argument), exercising per-request fault
    isolation: only that slot retires ``FinishReason.FAULT``.
``clock.skew``
    The engine's injected clock (``ChaosInjector.now``) jumps forward by
    ``skew_s`` seconds, exercising deadline expiry deterministically.

Faults fire from a *schedule* (explicit per-point consult indices — fully
deterministic) and/or seeded per-point Bernoulli *rates*; every firing is
recorded in :attr:`events`, so two runs with the same seed and schedule are
bit-identical.  The injector never imports the engine — it is a leaf
dependency consulted through small callables/flags.
"""
from __future__ import annotations

import time
from collections import defaultdict
from collections.abc import Iterable, Mapping

import numpy as np

#: The serving fault-point catalog (see module docstring).
FAULT_POINTS = ("pool.alloc", "runner.mixed", "logits.nan", "clock.skew")


class ChaosError(RuntimeError):
    """A transient injected failure (fault point ``runner.mixed``)."""


class ChaosInjector:
    """Deterministic fault injection over named fault points.

    Parameters
    ----------
    seed:
        Seeds one independent RNG stream per fault point (rates only).
    schedule:
        ``{point: iterable of consult indices}`` — ``fire(point)`` returns
        True exactly on those consults (0-based, per point).
    rates:
        ``{point: probability}`` — each consult additionally fires with the
        given seeded probability.
    skew_s:
        Seconds added to the injected clock each time ``clock.skew`` fires.
    points:
        The set of legal fault-point names (typo guard).  Defaults to
        :data:`FAULT_POINTS`; specializations (e.g. the training
        ``FailureInjector``) pass their own.
    """

    def __init__(self, seed: int = 0,
                 schedule: Mapping[str, Iterable[int]] | None = None,
                 rates: Mapping[str, float] | None = None,
                 skew_s: float = 60.0,
                 points: tuple[str, ...] = FAULT_POINTS):
        self.points = tuple(points)
        self.schedule = {p: frozenset(int(i) for i in ix)
                         for p, ix in (schedule or {}).items()}
        self.rates = {p: float(r) for p, r in (rates or {}).items()}
        unknown = (set(self.schedule) | set(self.rates)) - set(self.points)
        if unknown:
            raise ValueError(f"unknown fault points {sorted(unknown)}; "
                             f"known: {list(self.points)}")
        self.skew_s = float(skew_s)
        self.skew = 0.0
        self._counts: dict[str, int] = defaultdict(int)
        self._rngs = {p: np.random.RandomState((seed * 1000003 + k + 1)
                                               & 0x7FFFFFFF)
                      for k, p in enumerate(self.points)}
        #: chronological (point, consult_index) log of every firing
        self.events: list[tuple[str, int]] = []

    def fire(self, point: str, detail: int | None = None) -> bool:
        """Consult fault point ``point``; True when the fault fires.

        Each call advances the point's consult counter; ``detail`` (when
        given) overrides the index matched against the schedule — used by
        specializations that key on an external step number rather than the
        consult count."""
        if point not in self.points:
            raise ValueError(f"unknown fault point {point!r}")
        i = self._counts[point]
        self._counts[point] += 1
        idx = i if detail is None else int(detail)
        hit = idx in self.schedule.get(point, ())
        r = self.rates.get(point, 0.0)
        if not hit and r > 0.0:
            hit = bool(self._rngs[point].random_sample() < r)
        if hit:
            self.events.append((point, idx))
            if point == "clock.skew":
                self.skew += self.skew_s
        return hit

    def now(self) -> float:
        """The injected clock: wall time plus accumulated skew."""
        return time.time() + self.skew

    def count(self, point: str) -> int:
        """Number of times ``point`` has *fired* (not consulted)."""
        return sum(1 for p, _ in self.events if p == point)
