"""Continuous-batching serving engine over a paged KV cache.

The engine is split into a host-side :class:`Scheduler` (admission control,
the slot state machine, chunk budgeting) and a device-side
:class:`ModelRunner` (the compiled functions and the cache pytree), with
:class:`Engine` as the public facade driving one *unified mixed step* per
tick: up to ``chunk_tokens`` of prompt-chunk work from the prefilling slot
plus one decode token per decoding slot, packed into a single compiled call.
Decode latency stays flat while long prompts stream through in fixed-size
chunks — prefill no longer head-of-line-blocks in-flight decodes.

Slot state machine (``Scheduler``)::

    QUEUED --admit--> PREFILLING(offset) --chunks--> DECODING --eos/limit-->
    RETIRED

Admission reserves the request's full page need up front and, on
prefix-decomposable models (pure attention), starts the slot at
``offset = radix prefix hit``; each tick the mixed step advances the oldest
prefilling slot by up to ``chunk_tokens`` prompt rows, writing chunk KV
straight through the page table (``model.chunk_step`` — no dense gather of
the past).  When the chunk completes the prompt, the chunk logits' last
valid row samples the first token and the slot flips to DECODING.  Ticks
with no prefill work run a ``lax.scan`` of ``decode_chunk`` fused decode
steps as before.

Compiled-variant budget: the mixed step compiles once per chunk *buffer*
size — with ``chunk_tokens`` set that is one variant total; unset, the
whole suffix runs as a single chunk in a power-of-two-bucketed buffer
(≤ log2(max_len) variants).  This replaces the per-``(prefix_len,
suffix_len)`` prefill executable cache; the LRU bound
(``Engine.max_prefill_variants``) is kept as a backstop and still governs
the exact-length whole-prompt path used by non-decomposable mixers
(SSM / MLA / cross-attention), which cannot chunk.

Cache layout (``EngineConfig.cache_spec()``, ``CacheLayout.PAGED``): every
attention layer owns a ``[n_pages, page_size, ...]`` page pool allocated up
front via ``model.paged_cache_specs``; each live sequence holds a page
*table* (``[pages_per_seq]`` int32, shared logically across all layers —
pages are allocated in lockstep) mapping logical KV rows to pool pages.
Page 0 is the reserved *trash page*: retired batch rows keep their table
zeroed and ``pos = 0``, so the decode chunk's unconditional writes land
somewhere harmless; the mixed step likewise zeroes the prefilling slot's
row in the decode-side table.

Prefix reuse (``EngineConfig.prefix_cache``): a radix tree over page-sized
token chunks (``serving.paging.RadixCache``) shares full prompt pages
between requests by refcount — a prefix hit of ``s`` tokens skips their
recompute entirely: the slot starts prefilling at ``offset = s`` and the
chunks cover only the suffix.  A partially-matching page is shared
copy-on-write: the new request gets a fresh page, the donor's matched rows
are device-copied, and the chunks overwrite the divergent tail.  A prompt's
full pages are published to the tree when its prefill *completes* (pages
must be fully written before they can be matched), and admission holds
while a slot is prefilling so lookups never race an unpublished prefix.

Per-slot determinism: each request carries its own PRNG key and temperature,
and every slot decodes at its own position, so a request's output is
independent of whatever shares the batch with it.  (Exception: MoE layers —
expert capacity is routed jointly over the batch, so under capacity pressure
a request's routing can depend on concurrent traffic, as on any batched MoE
serving system.)
"""
from __future__ import annotations

import functools
import time
import warnings
from collections import OrderedDict, deque
from dataclasses import dataclass, field
from typing import Any

import numpy as np

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ArchConfig
from repro.core import round_up
from repro.launch.sharding import activation_mesh, tree_pspecs
from repro.models import model as M
from repro.models.params import is_spec
from repro.serving.config import CacheSpec, EngineConfig
from repro.serving.paging import PagePool, PrefixMatch, RadixCache


def bytes_tokenizer_encode(text: str, vocab: int) -> list[int]:
    return [b % vocab for b in text.encode("utf-8")]


def bytes_tokenizer_decode(tokens) -> str:
    return bytes(int(t) % 256 for t in tokens).decode("utf-8", errors="replace")


# ---------------------------------------------------------------------------
# Requests / results
# ---------------------------------------------------------------------------

@dataclass
class Request:
    rid: int
    prompt: list[int]
    max_new: int = 32
    temperature: float = 0.0
    seed: int = 0
    arrival_s: float = 0.0


@dataclass
class RequestResult:
    rid: int
    prompt: list[int]
    generated: list[int]
    arrival_s: float
    first_token_s: float
    finish_s: float
    #: wall-clock emission time of each generated token (tick granularity —
    #: tokens emitted by the same compiled call share a timestamp); drives
    #: inter-token-latency percentiles in the serving benchmark
    token_times_s: list[float] = field(default_factory=list)

    @property
    def tokens(self) -> list[int]:
        return list(self.prompt) + list(self.generated)

    @property
    def ttft_s(self) -> float:
        return self.first_token_s - self.arrival_s

    @property
    def latency_s(self) -> float:
        return self.finish_s - self.arrival_s

    @property
    def itl_s(self) -> list[float]:
        """Inter-token gaps (seconds) between consecutive emissions."""
        t = self.token_times_s
        return [b - a for a, b in zip(t, t[1:])]


@dataclass
class ServeStats:
    prefill_s: float = 0.0
    decode_s: float = 0.0
    tokens_out: int = 0
    prefills: int = 0
    chunks: int = 0
    mixed_steps: int = 0
    peak_active: int = 0
    prefix_hit_tokens: int = 0
    prefix_lookup_tokens: int = 0

    @property
    def tokens_per_s(self) -> float:
        return self.tokens_out / self.decode_s if self.decode_s else 0.0

    @property
    def prefix_hit_rate(self) -> float:
        return (self.prefix_hit_tokens / self.prefix_lookup_tokens
                if self.prefix_lookup_tokens else 0.0)


QUEUED = "queued"
PREFILLING = "prefilling"
DECODING = "decoding"


@dataclass
class _Slot:
    req: Request
    emitted: list[int] = field(default_factory=list)
    first_token_s: float = 0.0
    phase: str = DECODING
    offset: int = 0        # prompt rows already in pages (incl. radix hit)
    seq: int = 0           # admission order (FIFO chunk scheduling)
    key: Any = None        # request PRNG key until the first sample commits
    token_times: list[float] = field(default_factory=list)


_LEGACY_KWARGS = ("max_len", "max_slots", "prefill_bucket", "decode_chunk",
                  "eos_id", "max_queue", "kernel_mode", "quant")


# ---------------------------------------------------------------------------
# ModelRunner: the compiled pieces + the cache pytree
# ---------------------------------------------------------------------------

class ModelRunner:
    """Owns the device state (params, paged cache pools) and every compiled
    function the engine calls: the fused decode chunk, the unified mixed
    step (one compiled variant per chunk-buffer size), the exact-length
    whole-prompt prefill for non-decomposable mixers, and the COW page copy.
    Executables live in one LRU (`fns`) bounded by the caller-supplied
    variant limit."""

    def __init__(self, cfg: ArchConfig, params, config: EngineConfig):
        self.cfg = cfg
        self.page_size = config.page_size
        self.decode_chunk = config.decode_chunk
        self.eos_id = config.eos_id
        self.vocab = cfg.vocab_size
        # mesh-sharded serving: place params with the logical-axis TP rules
        # and every KV pool over its kv_heads axis (page tables stay
        # replicated host-side numpy — the Scheduler is device-agnostic)
        self.mesh = (config.mesh.build()
                     if config.mesh is not None and config.mesh.size > 1
                     else None)
        if self.mesh is not None:
            params = M.shard_params(cfg, params, self.mesh)
        self.params = params
        self.cache_specs = M.paged_cache_specs(cfg, config.max_batch,
                                               config.n_pages,
                                               config.page_size)
        self.caches = jax.tree.map(
            lambda s: jnp.zeros(s.shape, s.dtype or cfg.compute_dtype),
            self.cache_specs, is_leaf=is_spec)
        if self.mesh is not None:
            self.caches = jax.tree.map(
                jax.device_put, self.caches,
                tree_pspecs(self.cache_specs, self.mesh))
        self.decode_fn = jax.jit(self._traced(self._decode_chunk),
                                 donate_argnums=(1,))
        self.copy_fn = jax.jit(self._traced(self._copy_page),
                               donate_argnums=(0,))
        self.fns: OrderedDict[tuple, Any] = OrderedDict()

    def _traced(self, fn):
        """Trace-time mesh context: the model's ``constrain`` calls (and the
        Pallas ``shard_map`` wrappers) only see the mesh if it is set while
        jit *traces* the function, not when the executable is called."""
        if self.mesh is None:
            return fn

        @functools.wraps(fn)
        def wrapped(*args):
            with activation_mesh(self.mesh):
                return fn(*args)

        return wrapped

    # -- sampling / decode ------------------------------------------------

    def _sample(self, logits, temp, keys):
        """Per-slot sampling.  logits: [B,Vp]; temp: [B]; keys: [B,2] u32."""
        lf = logits[:, : self.vocab].astype(jnp.float32)
        greedy = jnp.argmax(lf, -1).astype(jnp.int32)

        def one(key, lg, t):
            return jax.random.categorical(key, lg / jnp.maximum(t, 1e-6))

        sampled = jax.vmap(one)(keys, lf, temp).astype(jnp.int32)
        nxt = jnp.where(temp > 0.0, sampled, greedy)
        keys = jax.vmap(lambda k: jax.random.split(k, 2)[1])(keys)
        return nxt, keys

    def _dec_body(self, params, pages, temp):
        """One decode step as a scan body — shared verbatim between the
        decode-only chunk and the mixed step, so a token's math does not
        depend on which tick shape produced it."""
        cfg = self.cfg

        def body(carry, _):
            caches, cur, pos, remaining, keys = carry
            active = remaining > 0
            logits, caches = M.decode_step(cfg, params, caches, cur[:, None],
                                           pos, pages=pages)
            nxt, keys = self._sample(logits[:, -1], temp, keys)
            nxt = jnp.where(active, nxt, cur)  # freeze finished slots
            step = active.astype(jnp.int32)
            remaining = remaining - step
            if self.eos_id is not None:
                remaining = jnp.where(active & (nxt == self.eos_id), 0,
                                      remaining)
            return (caches, nxt, pos + step, remaining, keys), nxt

        return body

    def _decode_chunk(self, params, caches, pages, cur, pos, remaining, temp,
                      keys):
        """``decode_chunk`` fused decode steps; emits [B, steps] tokens.
        ``pages`` [B, npp] is constant across the chunk (each request's full
        page need is reserved at admission); finished slots freeze — their
        table is re-pointed at the trash page on retirement, so the chunk's
        unconditional KV writes can never corrupt a reallocated page."""
        (caches, cur, pos, remaining, keys), toks = lax.scan(
            self._dec_body(params, pages, temp),
            (caches, cur, pos, remaining, keys), None,
            length=self.decode_chunk)
        return caches, cur, pos, remaining, keys, toks.T  # [B, steps]

    # -- the unified mixed step -------------------------------------------

    def _mixed(self, params, caches, chunk_toks, chunk_pages, chunk_past,
               chunk_len, chunk_temp, chunk_key, dec_pages, cur, pos,
               remaining, temp, keys):
        """One engine tick: a prompt chunk for the prefilling slot plus one
        decode step for every decoding slot, in a single compiled call.

        chunk_toks [1, C] (``chunk_len`` valid rows at absolute positions
        ``chunk_past + i``), chunk_pages [1, npp].  ``dec_pages`` is the
        batch page table with the prefilling slot's row zeroed, so the
        decode pass's unconditional write for that (frozen) row lands on the
        trash page.  The chunk's sampled token/key only matter on the tick
        the chunk completes the prompt — the host discards them otherwise."""
        logits, caches = M.chunk_step(self.cfg, params, caches, chunk_toks,
                                      chunk_pages, chunk_past, chunk_len)
        tok0, key0 = self._sample(logits[:, -1], chunk_temp[None],
                                  chunk_key[None])
        (caches, cur, pos, remaining, keys), toks = lax.scan(
            self._dec_body(params, dec_pages, temp),
            (caches, cur, pos, remaining, keys), None, length=1)
        return caches, tok0[0], key0[0], cur, pos, remaining, keys, toks.T

    def mixed_fn(self, C: int, limit: int):
        """The mixed-step executable for chunk-buffer size ``C`` (the only
        shape degree of freedom — chunk offset/length are traced scalars)."""
        return self._cached(
            ("mixed", C),
            lambda: jax.jit(self._traced(self._mixed), donate_argnums=(1,)),
            limit)

    # -- exact-length whole-prompt prefill (non-decomposable mixers) ------

    def _flat_rows(self, table, first: int, n: int):
        """Pool-row indices of logical rows ``[first, first + n)``."""
        j = jnp.arange(n, dtype=jnp.int32) + first
        return table[j // self.page_size] * self.page_size + j % self.page_size

    def _scatter_new(self, caches, small, table, slot, n: int):
        """Write a whole-prompt prefill's outputs into the big cache: kv_seq
        leaves scatter their ``n`` rows to logical rows ``[0, n)`` through
        the page table; stateful leaves (SSM state, cross image-KV)
        overwrite batch row ``slot``."""
        rows = self._flat_rows(table, 0, n)

        def w(spec, pool, sm):
            if "kv_seq" in spec.axes:
                R, P, ps = pool.shape[0], pool.shape[1], pool.shape[2]
                flat = pool.reshape(R, P * ps, *pool.shape[3:])
                flat = flat.at[:, rows].set(sm[:, 0].astype(pool.dtype))
                return flat.reshape(pool.shape)
            return pool.at[:, slot].set(sm[:, 0].astype(pool.dtype))

        return jax.tree.map(w, self.cache_specs, caches, small,
                            is_leaf=is_spec)

    def _whole_prefill(self, n: int, params, caches, tokens, table, slot,
                       temp1, rkey):
        """Exact-length whole-prompt prefill + cache insert (traceable —
        ``repro.analysis`` walks this jaxpr; ``whole_prefill_fn`` jits it)."""
        logits, small = M.prefill(self.cfg, params, {"tokens": tokens},
                                  full_kv=True)
        caches = self._scatter_new(caches, small, table, slot, n)
        t0, key1 = self._sample(logits[:, -1], temp1[None], rkey[None])
        return caches, t0[0], key1[0]

    def whole_prefill_fn(self, n: int, limit: int):
        """Jitted exact-length prefill + cache insert for mixers whose
        prefill is not prefix-decomposable (SSM / MLA / cross-attention —
        they cannot run as chunks over a paged past).  One compilation per
        prompt length, LRU-bounded like the mixed variants."""
        return self._cached(
            ("whole", n),
            lambda: jax.jit(
                self._traced(functools.partial(self._whole_prefill, n)),
                donate_argnums=(1,)),
            limit)

    def _cached(self, key, build, limit: int):
        fn = self.fns.pop(key, None)
        if fn is None:
            fn = build()
        self.fns[key] = fn  # (re)insert as most recently used
        while len(self.fns) > limit:
            self.fns.popitem(last=False)
        return fn

    # -- COW page copy ----------------------------------------------------

    def _copy_page(self, caches, src, dst):
        """Device copy page ``src`` -> ``dst`` in every KV pool (the COW half
        of a partial-page prefix share; the chunk prefill then overwrites
        the divergent tail rows of ``dst``)."""

        def cp(spec, pool):
            if "kv_seq" not in spec.axes:
                return pool
            return pool.at[:, dst].set(pool[:, src])

        return jax.tree.map(cp, self.cache_specs, caches, is_leaf=is_spec)


# ---------------------------------------------------------------------------
# Scheduler: admission, chunk budgeting, slot state machine
# ---------------------------------------------------------------------------

class Scheduler:
    """Host-side request bookkeeping: the bounded admission queue, per-slot
    numpy state (page tables, positions, budgets, PRNG keys), page/radix
    accounting, and the QUEUED → PREFILLING → DECODING → RETIRED state
    machine.  It decides *what* runs each tick (`next_chunk`); the
    :class:`ModelRunner` decides *how*."""

    def __init__(self, config: EngineConfig, decomposable: bool):
        B = config.max_batch
        self.config = config
        self.page_size = config.page_size
        self.max_batch = B
        self.npp = config.cache_spec().pages_per_seq
        self.pool = PagePool(config.n_pages)
        # Chunked prefill (and prefix reuse) require prefill to decompose
        # over the prompt: pure attention (incl. sliding-window) qualifies;
        # SSM mixers scan state across the whole prompt, cross-attn prefill
        # depends on the image, and this MLA prefill recomputes absorbed
        # latents — all excluded, and served by exact whole-prompt prefill.
        self.chunked = decomposable
        self.radix: RadixCache | None = (
            RadixCache(config.page_size, self.pool)
            if (config.prefix_cache and decomposable) else None)

        self.pages = np.zeros((B, self.npp), np.int32)  # 0 == trash page
        self.owned: list[list[int]] = [[] for _ in range(B)]  # page refs
        self.cur = np.zeros(B, np.int32)        # next input token per slot
        self.pos = np.zeros(B, np.int32)        # its logical cache row
        self.limit = np.zeros(B, np.int32)      # reserved rows (plen+max_new)
        self.remaining = np.zeros(B, np.int32)  # tokens still to emit
        self.temp = np.zeros(B, np.float32)
        self.keys = np.zeros((B, 2), np.uint32)

        self.queue: deque[Request] = deque()
        self.slots: list[_Slot | None] = [None] * B
        self.finished: list[RequestResult] = []
        self._seq = 0

    @property
    def num_active(self) -> int:
        return sum(s is not None for s in self.slots)

    @property
    def num_queued(self) -> int:
        return len(self.queue)

    def pages_needed(self, prompt_len: int, max_new: int) -> int:
        return -(-(prompt_len + max_new) // self.page_size)

    def prefilling_slot(self) -> int | None:
        """Index of the slot currently streaming its prompt (at most one:
        admission holds while a prefill is in flight)."""
        cands = [i for i, s in enumerate(self.slots)
                 if s is not None and s.phase == PREFILLING]
        if not cands:
            return None
        return min(cands, key=lambda j: self.slots[j].seq)

    def next_chunk(self) -> tuple[int, int] | None:
        """(slot, n): the next prompt chunk to run — up to ``chunk_tokens``
        rows of the oldest prefilling slot (the whole remaining suffix when
        chunking is off)."""
        i = self.prefilling_slot()
        if i is None:
            return None
        slot = self.slots[i]
        left = len(slot.req.prompt) - slot.offset
        ct = self.config.chunk_tokens
        return i, (left if ct is None else min(ct, left))

    def _ensure_free_pages(self, fresh_needed: int) -> bool:
        """True when the pool can supply ``fresh_needed`` pages, evicting
        radix-cached pages only if eviction actually gets there — a request
        that stays blocked must not cost the tree pages it cannot use."""
        if self.pool.num_free >= fresh_needed:
            return True
        if self.radix is None:
            return False
        if self.pool.num_free + self.radix.num_evictable() < fresh_needed:
            return False
        self.radix.evict(fresh_needed)
        return True

    def admit(self, runner: ModelRunner, stats: ServeStats,
              variant_limit: int):
        """Move queued requests into free batch rows.  FIFO with
        head-of-line blocking: when the head request's page need cannot be
        met even after radix eviction, admission stops until retirements
        free pages (no starvation of large requests).  On chunked
        (prefix-decomposable) models a newly admitted slot enters
        PREFILLING and admission holds until its prefill completes —
        lookups must never match pages that are not fully written and
        published; non-decomposable models prefill whole prompts inline."""
        free_rows = [i for i in range(self.max_batch)
                     if self.slots[i] is None]
        while self.queue and free_rows:
            if self.chunked and self.prefilling_slot() is not None:
                break
            req = self.queue[0]
            plen = len(req.prompt)
            need = self.pages_needed(plen, req.max_new)
            if self.radix is not None:
                ht, lt = self.radix.hit_tokens, self.radix.lookup_tokens
                m = self.radix.match(req.prompt, max_match=plen - 1)
            else:
                m = PrefixMatch()
            fresh_needed = need - len(m.full_pages)
            # Pin every matched page (and the COW donor) *before* eviction
            # can run: tree-only pages (refcount 1) are legitimate LRU
            # victims, and an unpinned match could be freed by the very
            # evict() that makes room for its own suffix — the page table
            # would then point at a page the pool hands to someone else.
            pinned = list(m.full_pages)
            if m.partial is not None:
                pinned.append(m.partial[0])
            for pid in pinned:
                self.pool.incref(pid)
            ok = self._ensure_free_pages(fresh_needed)
            if not ok and m.partial is not None:
                # The pinned donor may itself be the one page eviction is
                # short of (a request sized to the whole pool); retry with
                # the copy-on-write share dropped rather than deadlock.
                self.pool.decref(pinned.pop())
                self.radix.hit_tokens -= m.partial[1]
                m.partial = None
                m.tokens = len(m.full_pages) * self.page_size
                ok = self._ensure_free_pages(fresh_needed)
            if not ok:
                for pid in pinned:
                    self.pool.decref(pid)
                if self.radix is not None:  # blocked: don't count the lookup
                    self.radix.hit_tokens = ht
                    self.radix.lookup_tokens = lt
                break
            self.queue.popleft()
            i = free_rows.pop(0)
            s = m.tokens  # cached prefix length (<= plen - 1)
            shared = list(m.full_pages)  # pins transfer to slot ownership
            fresh = [self.pool.alloc() for _ in range(fresh_needed)]
            assert all(p is not None for p in fresh)
            table = np.zeros(self.npp, np.int32)
            table[: len(shared)] = shared
            table[len(shared): len(shared) + len(fresh)] = fresh
            if m.partial is not None:  # copy-on-write share of a partial page
                donor, _rows = m.partial
                runner.caches = runner.copy_fn(runner.caches,
                                               jnp.int32(donor),
                                               jnp.int32(fresh[0]))
                self.pool.decref(donor)  # COW copy done: release the pin

            key = jax.random.PRNGKey(req.seed ^ (req.rid * 0x9E3779B9))
            self.pages[i] = table
            self.owned[i] = shared + fresh
            self.limit[i] = plen + req.max_new
            self.temp[i] = req.temperature
            if self.chunked:
                # slot enters PREFILLING at the radix offset; the engine's
                # mixed ticks stream the suffix through in chunks
                slot = _Slot(req, phase=PREFILLING, offset=s, seq=self._seq,
                             key=np.asarray(key))
                self._seq += 1
                self.slots[i] = slot
                self.cur[i] = self.pos[i] = self.remaining[i] = 0
                break  # hold admission until this prefill completes
            # non-decomposable: exact-length whole-prompt prefill, inline
            assert s == 0 and m.partial is None
            toks = np.asarray(req.prompt, np.int32)[None]
            t0 = time.time()
            runner.caches, first, key1 = runner.whole_prefill_fn(
                plen, variant_limit)(
                    runner.params, runner.caches, jnp.asarray(toks),
                    jnp.asarray(table), jnp.int32(i),
                    jnp.float32(req.temperature), key)
            first = int(first)
            stats.prefill_s += time.time() - t0
            stats.prefills += 1
            now = time.time()
            self.slots[i] = _Slot(req, emitted=[first], first_token_s=now,
                                  phase=DECODING, seq=self._seq,
                                  token_times=[now])
            self._seq += 1
            self.cur[i], self.pos[i] = first, plen
            self.remaining[i] = req.max_new - 1
            self.keys[i] = np.asarray(key1)
            stats.tokens_out += 1
            if self.remaining[i] == 0 or first == self.config.eos_id:
                self.remaining[i] = 0
                self.retire(i, now)
                free_rows.append(i)

    def commit_prefill(self, i: int, first: int, key1, now: float,
                       stats: ServeStats) -> bool:
        """A chunk just completed slot ``i``'s prompt: sample committed,
        slot flips to DECODING (or retires immediately on eos / max_new=1).
        Publishes the prompt's full pages to the radix tree — only now are
        they fully written and safe to match.  Returns True if retired."""
        slot = self.slots[i]
        req = slot.req
        plen = len(req.prompt)
        if self.radix is not None:
            fp = plen // self.page_size
            self.radix.insert(req.prompt[: fp * self.page_size],
                              [int(self.pages[i][j]) for j in range(fp)])
        slot.phase = DECODING
        slot.emitted = [first]
        slot.first_token_s = now
        slot.token_times = [now]
        slot.key = None
        self.cur[i], self.pos[i] = first, plen
        self.remaining[i] = req.max_new - 1
        self.keys[i] = np.asarray(key1)
        stats.prefills += 1
        stats.tokens_out += 1
        if self.remaining[i] == 0 or first == self.config.eos_id:
            self.remaining[i] = 0
            self.retire(i, now)
            return True
        return False

    def retire(self, i: int, now: float):
        s = self.slots[i]
        self.finished.append(RequestResult(
            s.req.rid, s.req.prompt, s.emitted, s.req.arrival_s,
            s.first_token_s, now, token_times_s=list(s.token_times)))
        self.slots[i] = None
        for pid in self.owned[i]:
            self.pool.decref(pid)  # radix-held pages survive at rc >= 1
        self.owned[i] = []
        self.pages[i] = 0  # trash page: frozen-row writes land harmlessly
        self.pos[i] = 0
        self.cur[i] = 0

    def check_capacity(self, steps_bound: int):
        """Refuse to decode a slot past its reserved rows.

        Rows beyond the reservation would route to the trash page (never
        corrupt another sequence), but reaching that state means silently
        lost context — the admission bound (``submit``) should have made it
        impossible, so surface it as an explicit length error.
        """
        steps = np.minimum(self.remaining, steps_bound)
        for i, slot in enumerate(self.slots):
            if (slot is not None and slot.phase == DECODING
                    and self.pos[i] + steps[i] > self.limit[i]):
                raise RuntimeError(
                    f"slot {i} (rid={slot.req.rid}): decoding {int(steps[i])} "
                    f"steps from pos={int(self.pos[i])} overruns KV capacity "
                    f"{int(self.limit[i])} rows; request length accounting "
                    f"is inconsistent with admission control")


# ---------------------------------------------------------------------------
# Engine
# ---------------------------------------------------------------------------

class Engine:
    """Continuous-batching engine over a fixed params pytree.

    Construct with an :class:`~repro.serving.config.EngineConfig`::

        eng = Engine(cfg, params, EngineConfig(max_batch=8, max_len=512,
                                               page_size=64,
                                               chunk_tokens=32))

    The pre-paging keyword spelling (``max_slots=``, ``prefill_bucket=``,
    ...) still works through a ``DeprecationWarning`` shim: ``max_slots``
    maps to ``max_batch``, ``prefill_bucket`` is ignored (prefill is
    exact-length now), and the default page budget reproduces the legacy
    ``max_slots * max_len`` row capacity.
    """

    #: Bound on cached executables in the runner's LRU: mixed-step variants
    #: (one per chunk-buffer size — a handful at most) plus exact-length
    #: whole-prompt prefills for non-decomposable mixers (one per prompt
    #: length — the reason the bound exists).
    max_prefill_variants: int = 32

    def __init__(self, cfg: ArchConfig, params,
                 config: EngineConfig | int | None = None, **legacy):
        if isinstance(config, int):  # legacy positional: Engine(cfg, p, 512)
            legacy["max_len"] = config
            config = None
        if legacy:
            if config is not None:
                raise TypeError("pass either an EngineConfig or legacy "
                                "keyword arguments, not both")
            unknown = set(legacy) - set(_LEGACY_KWARGS)
            if unknown:
                raise TypeError(f"unknown Engine arguments: {sorted(unknown)}")
            warnings.warn(
                "Engine(max_len=..., max_slots=..., ...) is deprecated; pass "
                "EngineConfig (max_slots -> max_batch; prefill_bucket is "
                "gone — prefill is exact-length on the paged cache)",
                DeprecationWarning, stacklevel=2)
            legacy.pop("prefill_bucket", None)
            legacy["max_batch"] = legacy.pop("max_slots", 8)
            config = EngineConfig(**legacy)
        if config is None:
            config = EngineConfig()

        if config.kernel_mode is not None:
            cfg = cfg.with_(kernel_mode=config.kernel_mode)
        if config.quant is not None:
            cfg = cfg.with_(quant=config.quant)
        if cfg.quant == "w8a8":
            params = M.quantize_params(cfg, params)  # idempotent
        if config.mesh is not None and config.mesh.model > 1 \
                and cfg.num_experts and cfg.num_experts % config.mesh.model == 0:
            # expert-parallel decode: route tokens across the model axis via
            # the moe_specs/_moe_expert_block manual-axis path (each device
            # holds E/tp experts; the dispatch/combine gathers stay local
            # and one f32 psum merges the partial outputs)
            cfg = cfg.with_(moe_shard_map=True)
        self.cfg, self.params = cfg, params
        self.config = config
        self.cache_spec: CacheSpec = config.cache_spec()
        self.decode_chunk = config.decode_chunk
        self.chunk_tokens = config.chunk_tokens
        self.eos_id = config.eos_id
        self.max_queue = config.max_queue
        self.max_batch = config.max_batch
        self.max_len = config.max_len
        self.page_size = config.page_size
        self.npp = self.cache_spec.pages_per_seq
        self.stats = ServeStats()

        decomposable = (not cfg.use_mla and
                        all(sp.mixer not in ("ssm", "cross")
                            for sp in cfg.layer_specs()))
        self.runner = ModelRunner(cfg, self.params, config)
        self.sched = Scheduler(config, decomposable)
        self._next_rid = 0

    # -- state shared with the scheduler/runner (test-visible surface) ----

    @property
    def pool(self) -> PagePool:
        return self.sched.pool

    @property
    def radix(self) -> RadixCache | None:
        return self.sched.radix

    @property
    def num_active(self) -> int:
        return self.sched.num_active

    @property
    def num_queued(self) -> int:
        return self.sched.num_queued

    @property
    def prefix_hit_rate(self) -> float:
        return self.radix.hit_rate if self.radix else 0.0

    @property
    def _caches(self):
        return self.runner.caches

    @property
    def _prefill_fns(self):
        return self.runner.fns

    @property
    def _pages(self):
        return self.sched.pages

    @property
    def _remaining(self):
        return self.sched.remaining

    @property
    def _slots(self):
        return self.sched.slots

    def pages_needed(self, prompt_len: int, max_new: int) -> int:
        return self.sched.pages_needed(prompt_len, max_new)

    # -- admission --------------------------------------------------------

    def submit(self, prompt: list[int], max_new: int = 32,
               temperature: float = 0.0, seed: int = 0) -> int:
        """Admit a request; returns its rid.  Raises ``ValueError`` on
        malformed input or a request that can never fit (rows or pages) and
        ``RuntimeError`` on queue overflow (backpressure — callers should
        retry later)."""
        prompt = list(prompt)
        if not prompt:
            raise ValueError("empty prompt: a request must carry at least "
                             "one prompt token")
        if not all(isinstance(t, (int, np.integer)) and 0 <= t < self.cfg.vocab_size
                   for t in prompt):
            raise ValueError(f"prompt tokens must be ints in "
                             f"[0, {self.cfg.vocab_size})")
        if not isinstance(max_new, (int, np.integer)) or max_new < 1:
            raise ValueError(f"max_new={max_new!r} must be an int >= 1")
        if temperature < 0.0:
            raise ValueError(f"temperature={temperature} must be >= 0")
        if len(prompt) + max_new > self.max_len:
            raise ValueError(
                f"request needs {len(prompt) + max_new} cache rows > "
                f"max_len={self.max_len}")
        if self.pages_needed(len(prompt), max_new) > self.pool.n_pages - 1:
            raise ValueError(
                f"request needs {self.pages_needed(len(prompt), max_new)} "
                f"pages > pool capacity {self.pool.n_pages - 1}")
        if len(self.sched.queue) >= self.max_queue:
            raise RuntimeError("admission queue full")
        rid = self._next_rid
        self._next_rid += 1
        self.sched.queue.append(Request(rid, [int(t) for t in prompt],
                                        int(max_new), float(temperature),
                                        seed, arrival_s=time.time()))
        return rid

    # -- the tick ---------------------------------------------------------

    def _chunk_buf(self, n: int) -> int:
        """Static chunk-buffer size for an ``n``-token chunk: exactly
        ``chunk_tokens`` when chunking is on (one compiled variant total);
        otherwise the next power-of-two bucket (≤ log2(max_len) variants
        across all prompt lengths — this replaces the per-(prefix, suffix)
        executable cache)."""
        if self.chunk_tokens is not None:
            return self.chunk_tokens
        C = 8
        while C < n:
            C *= 2
        return min(C, round_up(self.max_len, 8))

    def _mixed_tick(self, i: int, n: int):
        """Run the unified mixed step: ``n`` prompt rows of prefilling slot
        ``i`` plus one decode step for every decoding slot."""
        sched, runner = self.sched, self.runner
        slot = sched.slots[i]
        C = self._chunk_buf(n)
        buf = np.zeros((1, C), np.int32)
        buf[0, :n] = slot.req.prompt[slot.offset: slot.offset + n]
        dec_pages = sched.pages.copy()
        dec_pages[i] = 0  # prefilling slot's frozen decode row -> trash page
        sched.check_capacity(1)
        before = sched.remaining.copy()
        t0 = time.time()
        (runner.caches, tok0, key1, cur, pos, remaining, keys, toks) = \
            runner.mixed_fn(C, self.max_prefill_variants)(
                runner.params, runner.caches, jnp.asarray(buf),
                jnp.asarray(sched.pages[i: i + 1]), jnp.int32(slot.offset),
                jnp.int32(n), jnp.float32(slot.req.temperature),
                jnp.asarray(slot.key), jnp.asarray(dec_pages),
                jnp.asarray(sched.cur), jnp.asarray(sched.pos),
                jnp.asarray(sched.remaining), jnp.asarray(sched.temp),
                jnp.asarray(sched.keys))
        toks = np.asarray(toks)
        sched.cur, sched.pos = np.array(cur), np.array(pos)
        sched.remaining, sched.keys = np.array(remaining), np.array(keys)
        self.stats.prefill_s += time.time() - t0
        self.stats.mixed_steps += 1
        now = time.time()
        self._emit(toks, before, now)
        slot.offset += n
        if slot.offset == len(slot.req.prompt):
            sched.commit_prefill(i, int(tok0), key1, now, self.stats)

    def _decode_tick(self):
        """Run one fused decode chunk (no prefill work pending)."""
        sched, runner = self.sched, self.runner
        sched.check_capacity(self.decode_chunk)
        before = sched.remaining.copy()
        t0 = time.time()
        (runner.caches, cur, pos, remaining, keys, toks) = runner.decode_fn(
            runner.params, runner.caches, jnp.asarray(sched.pages),
            jnp.asarray(sched.cur), jnp.asarray(sched.pos),
            jnp.asarray(sched.remaining), jnp.asarray(sched.temp),
            jnp.asarray(sched.keys))
        toks = np.asarray(toks)
        sched.cur, sched.pos = np.array(cur), np.array(pos)
        sched.remaining, sched.keys = np.array(remaining), np.array(keys)
        self.stats.decode_s += time.time() - t0
        self.stats.chunks += 1
        self._emit(toks, before, time.time())

    def _emit(self, toks, before, now: float):
        """Credit decoded tokens to their slots and retire finished ones.
        ``before`` (remaining at tick start) bounds each slot's share — a
        slot that was prefilling or frozen contributes nothing."""
        for i, slot in enumerate(self.sched.slots):
            if slot is None or before[i] == 0:
                continue
            take = toks[i][: before[i]]
            if self.eos_id is not None:
                stop = np.nonzero(take == self.eos_id)[0]
                if stop.size:
                    take = take[: stop[0] + 1]
            slot.emitted.extend(int(t) for t in take)
            slot.token_times.extend(now for _ in take)
            self.stats.tokens_out += len(take)
            if self.sched.remaining[i] == 0:
                self.sched.retire(i, now)

    def step(self) -> list[RequestResult]:
        """One scheduling iteration: admit, then run either the unified
        mixed step (prompt chunk + one decode step each) or a fused
        decode-only chunk.  Returns newly finished requests."""
        sched = self.sched
        sched.admit(self.runner, self.stats, self.max_prefill_variants)
        self.stats.peak_active = max(self.stats.peak_active, self.num_active)
        nc = sched.next_chunk()
        if nc is not None:
            self._mixed_tick(*nc)
        elif self.num_active:
            self._decode_tick()
        if self.radix is not None:
            self.stats.prefix_hit_tokens = self.radix.hit_tokens
            self.stats.prefix_lookup_tokens = self.radix.lookup_tokens
        out, sched.finished = sched.finished, []
        return out

    def run(self) -> list[RequestResult]:
        """Drive ``step`` until queue and slots drain; returns all results."""
        results = []
        while self.sched.queue or self.num_active:
            results.extend(self.step())
        return results

    # ------------------------------------------------------------------
    # batch-generate compatibility surface (seed API)
    # ------------------------------------------------------------------

    def generate(self, prompts: list[list[int]], max_new: int = 32,
                 temperature: float = 0.0, seed: int = 0):
        """Submit a closed batch and run it to completion.  Returns
        ``(sequences, stats)`` like the seed engine: ``sequences[i]`` is
        prompt + generated for ``prompts[i]``."""
        t_stats = ServeStats(prefill_s=-self.stats.prefill_s,
                             decode_s=-self.stats.decode_s,
                             tokens_out=-self.stats.tokens_out,
                             prefills=-self.stats.prefills,
                             chunks=-self.stats.chunks,
                             mixed_steps=-self.stats.mixed_steps)
        rids = [self.submit(p, max_new, temperature, seed=seed * 1000003 + i)
                for i, p in enumerate(prompts)]
        by_rid = {r.rid: r for r in self.run()}
        out = [by_rid[r].tokens for r in rids]
        t_stats.prefill_s += self.stats.prefill_s
        t_stats.decode_s += self.stats.decode_s
        t_stats.tokens_out += self.stats.tokens_out
        t_stats.prefills += self.stats.prefills
        t_stats.chunks += self.stats.chunks
        t_stats.mixed_steps += self.stats.mixed_steps
        t_stats.peak_active = self.stats.peak_active
        t_stats.prefix_hit_tokens = self.stats.prefix_hit_tokens
        t_stats.prefix_lookup_tokens = self.stats.prefix_lookup_tokens
        return out, t_stats
