"""Continuous-batching serving engine over a paged KV cache.

Requests enter a bounded queue (admission control), get prefilled one at a
time into *pages* of a shared KV pool, and decode together in a ``lax.scan``
over ``decode_chunk`` steps — the hot path is one compiled function, no
per-token Python dispatch.  Finished sequences release their pages and the
queue refills the freed batch row without recompiling anything.

Cache layout (``EngineConfig.cache_spec()``, ``CacheLayout.PAGED``): every
attention layer owns a ``[n_pages, page_size, ...]`` page pool allocated up
front via ``model.paged_cache_specs``; each live sequence holds a page
*table* (``[pages_per_seq]`` int32, shared logically across all layers —
pages are allocated in lockstep) mapping logical KV rows to pool pages.
Page 0 is the reserved *trash page*: retired batch rows keep their table
zeroed and ``pos = 0``, so the decode chunk's unconditional writes land
somewhere harmless.  SSM state and cross-attention image KV have no
sequence axis and stay slot-indexed ``[max_batch, ...]``.

Prefix reuse (``EngineConfig.prefix_cache``): a radix tree over page-sized
token chunks (``serving.paging.RadixCache``) shares full prompt pages
between requests by refcount — a prefix hit of ``s`` tokens skips their
recompute entirely: the engine gathers the cached rows and prefills only
the suffix (``model.prefill(past=..., past_len=s)``), aligning the last
query with the last key.  A partially-matching page is shared
copy-on-write: the new request gets a fresh page, the donor's matched rows
are device-copied, and the suffix overwrites the divergent tail.  Prefill
compiles once per distinct ``(prefix_len, suffix_len)`` pair — exact
lengths, no pad rows (the left-pad ``prefill_bucket`` machinery is gone,
which also makes SSM/hybrid prefill exact by construction) — with the
compiled variants kept in an LRU cache bounded by
``Engine.max_prefill_variants``.

Per-slot determinism: each request carries its own PRNG key and temperature,
and every slot decodes at its own position, so a request's output is
independent of whatever shares the batch with it.  (Exception: MoE layers —
expert capacity is routed jointly over the batch, so under capacity pressure
a request's routing can depend on concurrent traffic, as on any batched MoE
serving system.)
"""
from __future__ import annotations

import time
import warnings
from collections import OrderedDict, deque
from dataclasses import dataclass, field
from typing import Any

import numpy as np

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ArchConfig
from repro.models import model as M
from repro.models.params import is_spec
from repro.serving.config import CacheSpec, EngineConfig
from repro.serving.paging import PagePool, PrefixMatch, RadixCache


def bytes_tokenizer_encode(text: str, vocab: int) -> list[int]:
    return [b % vocab for b in text.encode("utf-8")]


def bytes_tokenizer_decode(tokens) -> str:
    return bytes(int(t) % 256 for t in tokens).decode("utf-8", errors="replace")


# ---------------------------------------------------------------------------
# Requests / results
# ---------------------------------------------------------------------------

@dataclass
class Request:
    rid: int
    prompt: list[int]
    max_new: int = 32
    temperature: float = 0.0
    seed: int = 0
    arrival_s: float = 0.0


@dataclass
class RequestResult:
    rid: int
    prompt: list[int]
    generated: list[int]
    arrival_s: float
    first_token_s: float
    finish_s: float

    @property
    def tokens(self) -> list[int]:
        return list(self.prompt) + list(self.generated)

    @property
    def ttft_s(self) -> float:
        return self.first_token_s - self.arrival_s

    @property
    def latency_s(self) -> float:
        return self.finish_s - self.arrival_s


@dataclass
class ServeStats:
    prefill_s: float = 0.0
    decode_s: float = 0.0
    tokens_out: int = 0
    prefills: int = 0
    chunks: int = 0
    peak_active: int = 0
    prefix_hit_tokens: int = 0
    prefix_lookup_tokens: int = 0

    @property
    def tokens_per_s(self) -> float:
        return self.tokens_out / self.decode_s if self.decode_s else 0.0

    @property
    def prefix_hit_rate(self) -> float:
        return (self.prefix_hit_tokens / self.prefix_lookup_tokens
                if self.prefix_lookup_tokens else 0.0)


@dataclass
class _Slot:
    req: Request
    emitted: list[int] = field(default_factory=list)
    first_token_s: float = 0.0


_LEGACY_KWARGS = ("max_len", "max_slots", "prefill_bucket", "decode_chunk",
                  "eos_id", "max_queue", "kernel_mode", "quant")


# ---------------------------------------------------------------------------
# Engine
# ---------------------------------------------------------------------------

class Engine:
    """Continuous-batching engine over a fixed params pytree.

    Construct with an :class:`~repro.serving.config.EngineConfig`::

        eng = Engine(cfg, params, EngineConfig(max_batch=8, max_len=512,
                                               page_size=64))

    The pre-paging keyword spelling (``max_slots=``, ``prefill_bucket=``,
    ...) still works through a ``DeprecationWarning`` shim: ``max_slots``
    maps to ``max_batch``, ``prefill_bucket`` is ignored (prefill is
    exact-length now), and the default page budget reproduces the legacy
    ``max_slots * max_len`` row capacity.
    """

    #: Bound on cached suffix-prefill executables (one per distinct
    #: ``(prefix_len, suffix_len)`` pair, LRU-evicted beyond this) — varied
    #: prompt lengths must not accumulate XLA executables without limit.
    max_prefill_variants: int = 32

    def __init__(self, cfg: ArchConfig, params,
                 config: EngineConfig | int | None = None, **legacy):
        if isinstance(config, int):  # legacy positional: Engine(cfg, p, 512)
            legacy["max_len"] = config
            config = None
        if legacy:
            if config is not None:
                raise TypeError("pass either an EngineConfig or legacy "
                                "keyword arguments, not both")
            unknown = set(legacy) - set(_LEGACY_KWARGS)
            if unknown:
                raise TypeError(f"unknown Engine arguments: {sorted(unknown)}")
            warnings.warn(
                "Engine(max_len=..., max_slots=..., ...) is deprecated; pass "
                "EngineConfig (max_slots -> max_batch; prefill_bucket is "
                "gone — prefill is exact-length on the paged cache)",
                DeprecationWarning, stacklevel=2)
            legacy.pop("prefill_bucket", None)
            legacy["max_batch"] = legacy.pop("max_slots", 8)
            config = EngineConfig(**legacy)
        if config is None:
            config = EngineConfig()

        if config.kernel_mode is not None:
            cfg = cfg.with_(kernel_mode=config.kernel_mode)
        if config.quant is not None:
            cfg = cfg.with_(quant=config.quant)
        if cfg.quant == "w8a8":
            params = M.quantize_params(cfg, params)  # idempotent
        self.cfg, self.params = cfg, params
        self.config = config
        self.cache_spec: CacheSpec = config.cache_spec()
        self.decode_chunk = config.decode_chunk
        self.eos_id = config.eos_id
        self.max_queue = config.max_queue
        self.max_batch = config.max_batch
        self.max_len = config.max_len
        self.stats = ServeStats()

        ps = config.page_size
        self.page_size = ps
        self.npp = self.cache_spec.pages_per_seq  # table width (pages/seq)
        self.pool = PagePool(config.n_pages)
        # Prefix reuse requires prefill to decompose over the prompt: pure
        # attention (incl. sliding-window) qualifies; SSM mixers scan state
        # across the whole prompt, cross-attn prefill depends on the image,
        # and this MLA prefill recomputes absorbed latents — all excluded.
        decomposable = (not cfg.use_mla and
                        all(sp.mixer not in ("ssm", "cross")
                            for sp in cfg.layer_specs()))
        self.radix: RadixCache | None = (
            RadixCache(ps, self.pool)
            if (config.prefix_cache and decomposable) else None)

        self._cache_specs = M.paged_cache_specs(cfg, self.max_batch,
                                                config.n_pages, ps)
        self._caches = jax.tree.map(
            lambda s: jnp.zeros(s.shape, s.dtype or cfg.compute_dtype),
            self._cache_specs, is_leaf=is_spec)
        B = self.max_batch
        self._pages = np.zeros((B, self.npp), np.int32)  # 0 == trash page
        self._owned: list[list[int]] = [[] for _ in range(B)]  # page refs
        self._cur = np.zeros(B, np.int32)        # next input token per slot
        self._pos = np.zeros(B, np.int32)        # its logical cache row
        self._limit = np.zeros(B, np.int32)      # reserved rows (plen+max_new)
        self._remaining = np.zeros(B, np.int32)  # tokens still to emit
        self._temp = np.zeros(B, np.float32)
        self._keys = np.zeros((B, 2), np.uint32)

        self._queue: deque[Request] = deque()
        self._slots: list[_Slot | None] = [None] * B
        self._finished: list[RequestResult] = []
        self._next_rid = 0

        self._decode_fn = jax.jit(self._decode_chunk, donate_argnums=(1,))
        self._prefill_fns: OrderedDict[tuple[int, int], Any] = OrderedDict()
        self._copy_fn = jax.jit(self._copy_page, donate_argnums=(0,))

    # ------------------------------------------------------------------
    # compiled pieces
    # ------------------------------------------------------------------

    def _sample(self, logits, temp, keys):
        """Per-slot sampling.  logits: [B,Vp]; temp: [B]; keys: [B,2] u32."""
        lf = logits[:, : self.cfg.vocab_size].astype(jnp.float32)
        greedy = jnp.argmax(lf, -1).astype(jnp.int32)

        def one(key, lg, t):
            return jax.random.categorical(key, lg / jnp.maximum(t, 1e-6))

        sampled = jax.vmap(one)(keys, lf, temp).astype(jnp.int32)
        nxt = jnp.where(temp > 0.0, sampled, greedy)
        keys = jax.vmap(lambda k: jax.random.split(k, 2)[1])(keys)
        return nxt, keys

    def _decode_chunk(self, params, caches, pages, cur, pos, remaining, temp,
                      keys):
        """``decode_chunk`` fused decode steps; emits [B, steps] tokens.
        ``pages`` [B, npp] is constant across the chunk (each request's full
        page need is reserved at admission); finished slots freeze — their
        table is re-pointed at the trash page on retirement, so the chunk's
        unconditional KV writes can never corrupt a reallocated page."""
        cfg = self.cfg

        def body(carry, _):
            caches, cur, pos, remaining, keys = carry
            active = remaining > 0
            logits, caches = M.decode_step(cfg, params, caches, cur[:, None],
                                           pos, pages=pages)
            nxt, keys = self._sample(logits[:, -1], temp, keys)
            nxt = jnp.where(active, nxt, cur)  # freeze finished slots
            step = active.astype(jnp.int32)
            remaining = remaining - step
            if self.eos_id is not None:
                remaining = jnp.where(active & (nxt == self.eos_id), 0,
                                      remaining)
            return (caches, nxt, pos + step, remaining, keys), nxt

        (caches, cur, pos, remaining, keys), toks = lax.scan(
            body, (caches, cur, pos, remaining, keys), None,
            length=self.decode_chunk)
        return caches, cur, pos, remaining, keys, toks.T  # [B, steps]

    def _copy_page(self, caches, src, dst):
        """Device copy page ``src`` -> ``dst`` in every KV pool (the COW half
        of a partial-page prefix share; the suffix prefill then overwrites
        the divergent tail rows of ``dst``)."""

        def cp(spec, pool):
            if "kv_seq" not in spec.axes:
                return pool
            return pool.at[:, dst].set(pool[:, src])

        return jax.tree.map(cp, self._cache_specs, caches, is_leaf=is_spec)

    def _flat_rows(self, table, first: int, n: int):
        """Pool-row indices of logical rows ``[first, first + n)``."""
        j = jnp.arange(n, dtype=jnp.int32) + first
        return table[j // self.page_size] * self.page_size + j % self.page_size

    def _gather_past(self, caches, table, s: int):
        """Dense per-layer [1, s, ...] KV of the cached prefix (rows 0..s-1
        read through the page table) — the ``past`` tree for suffix prefill.
        Only reached for prefix-decomposable (pure-attention) models, where
        every cache leaf has a kv_seq axis."""
        rows = self._flat_rows(table, 0, s)

        def g(spec, pool):
            assert "kv_seq" in spec.axes, spec.axes
            R, P, ps = pool.shape[0], pool.shape[1], pool.shape[2]
            flat = pool.reshape(R, P * ps, *pool.shape[3:])
            return flat[:, rows][:, None]  # [R, 1, s, ...]

        return jax.tree.map(g, self._cache_specs, caches, is_leaf=is_spec)

    def _scatter_new(self, caches, small, table, slot, s: int, sb: int):
        """Write a suffix prefill's outputs into the big cache: kv_seq leaves
        scatter their ``sb`` new rows to logical rows ``[s, s+sb)`` through
        the page table; stateful leaves (SSM state, cross image-KV) overwrite
        batch row ``slot``."""
        rows = self._flat_rows(table, s, sb)

        def w(spec, pool, sm):
            if "kv_seq" in spec.axes:
                R, P, ps = pool.shape[0], pool.shape[1], pool.shape[2]
                flat = pool.reshape(R, P * ps, *pool.shape[3:])
                flat = flat.at[:, rows].set(sm[:, 0].astype(pool.dtype))
                return flat.reshape(pool.shape)
            return pool.at[:, slot].set(sm[:, 0].astype(pool.dtype))

        return jax.tree.map(w, self._cache_specs, caches, small,
                            is_leaf=is_spec)

    def _prefill_fn(self, s: int, sb: int):
        """Jitted suffix-prefill + cache insert; one compilation per distinct
        (prefix_len, suffix_len) pair — prompts are exact-length, no pad
        rows.  Varied traffic produces arbitrarily many distinct pairs, so
        the cache keeps only the ``max_prefill_variants`` most recently used
        executables and recompiles on demand beyond that."""
        key = (s, sb)
        fn = self._prefill_fns.pop(key, None)
        if fn is None:
            cfg = self.cfg

            def prefill(params, caches, tokens, table, slot, temp1, rkey):
                past = self._gather_past(caches, table, s) if s else None
                logits, small = M.prefill(cfg, params, {"tokens": tokens},
                                          past=past, past_len=s, full_kv=True)
                caches = self._scatter_new(caches, small, table, slot, s, sb)
                t0, keys1 = self._sample(logits[:, -1], temp1[None],
                                         rkey[None])
                return caches, t0[0], keys1[0]

            fn = jax.jit(prefill, donate_argnums=(1,))
        self._prefill_fns[key] = fn  # (re)insert as most recently used
        while len(self._prefill_fns) > self.max_prefill_variants:
            self._prefill_fns.popitem(last=False)
        return fn

    # ------------------------------------------------------------------
    # scheduling
    # ------------------------------------------------------------------

    def pages_needed(self, prompt_len: int, max_new: int) -> int:
        return -(-(prompt_len + max_new) // self.page_size)

    def submit(self, prompt: list[int], max_new: int = 32,
               temperature: float = 0.0, seed: int = 0) -> int:
        """Admit a request; returns its rid.  Raises ``ValueError`` when the
        request can never fit (rows or pages) and ``RuntimeError`` on queue
        overflow (backpressure — callers should retry later)."""
        if not prompt:
            raise ValueError("empty prompt")
        if max_new < 1:
            raise ValueError("max_new must be >= 1")
        if len(prompt) + max_new > self.max_len:
            raise ValueError(
                f"request needs {len(prompt) + max_new} cache rows > "
                f"max_len={self.max_len}")
        if self.pages_needed(len(prompt), max_new) > self.pool.n_pages - 1:
            raise ValueError(
                f"request needs {self.pages_needed(len(prompt), max_new)} "
                f"pages > pool capacity {self.pool.n_pages - 1}")
        if len(self._queue) >= self.max_queue:
            raise RuntimeError("admission queue full")
        rid = self._next_rid
        self._next_rid += 1
        self._queue.append(Request(rid, list(prompt), max_new,
                                   float(temperature), seed,
                                   arrival_s=time.time()))
        return rid

    @property
    def num_active(self) -> int:
        return sum(s is not None for s in self._slots)

    @property
    def num_queued(self) -> int:
        return len(self._queue)

    @property
    def prefix_hit_rate(self) -> float:
        return self.radix.hit_rate if self.radix else 0.0

    def _ensure_free_pages(self, fresh_needed: int) -> bool:
        """True when the pool can supply ``fresh_needed`` pages, evicting
        radix-cached pages only if eviction actually gets there — a request
        that stays blocked must not cost the tree pages it cannot use."""
        if self.pool.num_free >= fresh_needed:
            return True
        if self.radix is None:
            return False
        if self.pool.num_free + self.radix.num_evictable() < fresh_needed:
            return False
        self.radix.evict(fresh_needed)
        return True

    def _admit(self):
        """Prefill queued requests into free batch rows.  FIFO with
        head-of-line blocking: when the head request's page need cannot be
        met even after radix eviction, admission stops until retirements
        free pages (no starvation of large requests)."""
        free_rows = [i for i in range(self.max_batch)
                     if self._slots[i] is None]
        while self._queue and free_rows:
            req = self._queue[0]
            plen = len(req.prompt)
            need = self.pages_needed(plen, req.max_new)
            if self.radix is not None:
                ht, lt = self.radix.hit_tokens, self.radix.lookup_tokens
                m = self.radix.match(req.prompt, max_match=plen - 1)
            else:
                m = PrefixMatch()
            fresh_needed = need - len(m.full_pages)
            # Pin every matched page (and the COW donor) *before* eviction
            # can run: tree-only pages (refcount 1) are legitimate LRU
            # victims, and an unpinned match could be freed by the very
            # evict() that makes room for its own suffix — the page table
            # would then point at a page the pool hands to someone else.
            pinned = list(m.full_pages)
            if m.partial is not None:
                pinned.append(m.partial[0])
            for pid in pinned:
                self.pool.incref(pid)
            ok = self._ensure_free_pages(fresh_needed)
            if not ok and m.partial is not None:
                # The pinned donor may itself be the one page eviction is
                # short of (a request sized to the whole pool); retry with
                # the copy-on-write share dropped rather than deadlock.
                self.pool.decref(pinned.pop())
                self.radix.hit_tokens -= m.partial[1]
                m.partial = None
                m.tokens = len(m.full_pages) * self.page_size
                ok = self._ensure_free_pages(fresh_needed)
            if not ok:
                for pid in pinned:
                    self.pool.decref(pid)
                if self.radix is not None:  # blocked: don't count the lookup
                    self.radix.hit_tokens = ht
                    self.radix.lookup_tokens = lt
                break
            self._queue.popleft()
            i = free_rows.pop(0)
            s = m.tokens  # cached prefix length (<= plen - 1)
            shared = list(m.full_pages)  # pins transfer to slot ownership
            fresh = [self.pool.alloc() for _ in range(fresh_needed)]
            assert all(p is not None for p in fresh)
            table = np.zeros(self.npp, np.int32)
            table[: len(shared)] = shared
            table[len(shared): len(shared) + len(fresh)] = fresh
            if m.partial is not None:  # copy-on-write share of a partial page
                donor, _rows = m.partial
                self._caches = self._copy_fn(self._caches, jnp.int32(donor),
                                             jnp.int32(fresh[0]))
                self.pool.decref(donor)  # COW copy done: release the pin

            toks = np.asarray(req.prompt[s:], np.int32)[None]  # exact length
            key = jax.random.PRNGKey(req.seed ^ (req.rid * 0x9E3779B9))
            t0 = time.time()
            self._caches, first, key1 = self._prefill_fn(s, plen - s)(
                self.params, self._caches, jnp.asarray(toks),
                jnp.asarray(table), jnp.int32(i),
                jnp.float32(req.temperature), key)
            first = int(first)
            self.stats.prefill_s += time.time() - t0
            self.stats.prefills += 1
            if self.radix is not None:  # publish full prompt pages for reuse
                fp = plen // self.page_size
                self.radix.insert(req.prompt[: fp * self.page_size],
                                  [int(table[j]) for j in range(fp)])
            now = time.time()
            self._slots[i] = _Slot(req, emitted=[first], first_token_s=now)
            self._pages[i] = table
            self._owned[i] = shared + fresh
            self._cur[i], self._pos[i] = first, plen
            self._limit[i] = plen + req.max_new
            self._remaining[i] = req.max_new - 1
            self._temp[i] = req.temperature
            self._keys[i] = np.asarray(key1)
            self.stats.tokens_out += 1
            if self._remaining[i] == 0 or first == self.eos_id:
                self._remaining[i] = 0
                self._retire(i, now)
                free_rows.append(i)

    def _retire(self, i: int, now: float):
        s = self._slots[i]
        self._finished.append(RequestResult(
            s.req.rid, s.req.prompt, s.emitted, s.req.arrival_s,
            s.first_token_s, now))
        self._slots[i] = None
        for pid in self._owned[i]:
            self.pool.decref(pid)  # radix-held pages survive at rc >= 1
        self._owned[i] = []
        self._pages[i] = 0  # trash page: frozen-row writes land harmlessly
        self._pos[i] = 0
        self._cur[i] = 0

    def _check_capacity(self):
        """Refuse to decode a slot past its reserved rows.

        Rows beyond the reservation would route to the trash page (never
        corrupt another sequence), but reaching that state means silently
        lost context — the admission bound (``submit``) should have made it
        impossible, so surface it as an explicit length error.
        """
        steps = np.minimum(self._remaining, self.decode_chunk)
        for i, slot in enumerate(self._slots):
            if slot is not None and self._pos[i] + steps[i] > self._limit[i]:
                raise RuntimeError(
                    f"slot {i} (rid={slot.req.rid}): decoding {int(steps[i])} "
                    f"steps from pos={int(self._pos[i])} overruns KV capacity "
                    f"{int(self._limit[i])} rows; request length accounting "
                    f"is inconsistent with admission control")

    def step(self) -> list[RequestResult]:
        """One scheduling iteration: admit into free batch rows, run one
        compiled decode chunk, evict finished sequences.  Returns newly
        finished."""
        self._admit()
        self.stats.peak_active = max(self.stats.peak_active, self.num_active)
        if self.num_active:
            self._check_capacity()
            before = self._remaining.copy()
            t0 = time.time()
            (self._caches, cur, pos, remaining, keys, toks) = self._decode_fn(
                self.params, self._caches, jnp.asarray(self._pages),
                jnp.asarray(self._cur), jnp.asarray(self._pos),
                jnp.asarray(self._remaining), jnp.asarray(self._temp),
                jnp.asarray(self._keys))
            toks = np.asarray(toks)
            self._cur, self._pos = np.array(cur), np.array(pos)
            self._remaining, self._keys = np.array(remaining), np.array(keys)
            self.stats.decode_s += time.time() - t0
            self.stats.chunks += 1
            now = time.time()
            for i, slot in enumerate(self._slots):
                if slot is None:
                    continue
                take = toks[i][: before[i]]
                if self.eos_id is not None:
                    stop = np.nonzero(take == self.eos_id)[0]
                    if stop.size:
                        take = take[: stop[0] + 1]
                slot.emitted.extend(int(t) for t in take)
                self.stats.tokens_out += len(take)
                if self._remaining[i] == 0:
                    self._retire(i, now)
        if self.radix is not None:
            self.stats.prefix_hit_tokens = self.radix.hit_tokens
            self.stats.prefix_lookup_tokens = self.radix.lookup_tokens
        out, self._finished = self._finished, []
        return out

    def run(self) -> list[RequestResult]:
        """Drive ``step`` until queue and slots drain; returns all results."""
        results = []
        while self._queue or self.num_active:
            results.extend(self.step())
        return results

    # ------------------------------------------------------------------
    # batch-generate compatibility surface (seed API)
    # ------------------------------------------------------------------

    def generate(self, prompts: list[list[int]], max_new: int = 32,
                 temperature: float = 0.0, seed: int = 0):
        """Submit a closed batch and run it to completion.  Returns
        ``(sequences, stats)`` like the seed engine: ``sequences[i]`` is
        prompt + generated for ``prompts[i]``."""
        t_stats = ServeStats(prefill_s=-self.stats.prefill_s,
                             decode_s=-self.stats.decode_s,
                             tokens_out=-self.stats.tokens_out,
                             prefills=-self.stats.prefills,
                             chunks=-self.stats.chunks)
        rids = [self.submit(p, max_new, temperature, seed=seed * 1000003 + i)
                for i, p in enumerate(prompts)]
        by_rid = {r.rid: r for r in self.run()}
        out = [by_rid[r].tokens for r in rids]
        t_stats.prefill_s += self.stats.prefill_s
        t_stats.decode_s += self.stats.decode_s
        t_stats.tokens_out += self.stats.tokens_out
        t_stats.prefills += self.stats.prefills
        t_stats.chunks += self.stats.chunks
        t_stats.peak_active = self.stats.peak_active
        t_stats.prefix_hit_tokens = self.stats.prefix_hit_tokens
        t_stats.prefix_lookup_tokens = self.stats.prefix_lookup_tokens
        return out, t_stats
