"""Continuous-batching serving engine.

Requests enter a bounded queue (admission control), get prefilled one at a
time into a free *slot* of a fixed-size batched KV cache, and decode together
in a ``lax.scan`` over ``decode_chunk`` steps — the hot path is one compiled
function, no per-token Python dispatch.  Finished sequences are evicted and
the freed slot is re-prefilled from the queue without recompiling anything
(prefill compiles once per prompt-length bucket; the decode chunk compiles
once, period).

Cache layout: every slot owns row ``i`` of a ``[slots, max_len]`` KV cache
allocated up front via ``model.cache_specs`` — global-attention layers use a
linear region written at ``pos``, sliding-window layers a ring written at
``pos % window``, SSM layers a constant-size state.  This replaces the seed
engine's ``grow_cache`` (a full-tree ``jnp.pad`` per generate call).

Per-slot determinism: each request carries its own PRNG key and temperature,
and every slot decodes at its own position, so a request's output is
independent of whatever shares the batch with it.  (Exception: MoE layers —
expert capacity is routed jointly over the batch, so under capacity pressure
a request's routing can depend on concurrent traffic, as on any batched MoE
serving system.)
"""
from __future__ import annotations

import time
import warnings
from collections import deque
from dataclasses import dataclass, field
from typing import Any

import numpy as np

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ArchConfig
from repro.core import round_up
from repro.models import model as M
from repro.models.params import is_spec


def bytes_tokenizer_encode(text: str, vocab: int) -> list[int]:
    return [b % vocab for b in text.encode("utf-8")]


def bytes_tokenizer_decode(tokens) -> str:
    return bytes(int(t) % 256 for t in tokens).decode("utf-8", errors="replace")


def grow_cache(cfg: ArchConfig, caches, new_len: int):
    """Legacy cache growth: pad every kv_seq dim to ``new_len``.  The engine
    no longer uses this (slots are fixed-size); kept as the reference path for
    tests and the serving benchmark's seed-style baseline."""
    specs = M.cache_specs(cfg, 1, new_len)

    def grow(spec, leaf):
        if "kv_seq" not in spec.axes:
            return leaf
        axis = spec.axes.index("kv_seq")
        target = spec.shape[axis]
        pad = target - leaf.shape[axis]
        if pad <= 0:
            return leaf
        widths = [(0, 0)] * leaf.ndim
        widths[axis] = (0, pad)
        return jnp.pad(leaf, widths)

    return jax.tree.map(grow, specs, caches, is_leaf=lambda x: is_spec(x))


# ---------------------------------------------------------------------------
# Requests / results
# ---------------------------------------------------------------------------

@dataclass
class Request:
    rid: int
    prompt: list[int]
    max_new: int = 32
    temperature: float = 0.0
    seed: int = 0
    arrival_s: float = 0.0


@dataclass
class RequestResult:
    rid: int
    prompt: list[int]
    generated: list[int]
    arrival_s: float
    first_token_s: float
    finish_s: float

    @property
    def tokens(self) -> list[int]:
        return list(self.prompt) + list(self.generated)

    @property
    def ttft_s(self) -> float:
        return self.first_token_s - self.arrival_s

    @property
    def latency_s(self) -> float:
        return self.finish_s - self.arrival_s


@dataclass
class ServeStats:
    prefill_s: float = 0.0
    decode_s: float = 0.0
    tokens_out: int = 0
    prefills: int = 0
    chunks: int = 0

    @property
    def tokens_per_s(self) -> float:
        return self.tokens_out / self.decode_s if self.decode_s else 0.0


@dataclass
class _Slot:
    req: Request
    emitted: list[int] = field(default_factory=list)
    first_token_s: float = 0.0


# ---------------------------------------------------------------------------
# Engine
# ---------------------------------------------------------------------------

class Engine:
    """Continuous-batching engine over a fixed params pytree.

    Parameters
    ----------
    max_slots:      concurrent sequences (the decode batch dimension)
    max_len:        per-slot KV capacity; admission requires
                    ``bucketed_prompt + max_new <= max_len``
    prefill_bucket: prompts are left-padded to a multiple of this, bounding
                    the number of prefill compilations.  Pad rows are dead:
                    the per-slot ``start`` offset excludes them from prefill
                    attention and decode validity and shifts RoPE so real
                    tokens sit at positions 0..len-1 — outputs are invariant
                    to the bucket size.  (Exception: SSM/hybrid layers scan
                    pad tokens into their recurrent state — use
                    ``prefill_bucket=1`` there for exact-length prompts.)
    decode_chunk:   scan steps per compiled decode call (the scheduler syncs
                    with the host — evict/admit — once per chunk)
    eos_id:         optional stop token (checked inside the scan)
    max_queue:      admission-control bound; ``submit`` refuses beyond it
    kernel_mode:    override ``cfg.kernel_mode`` (reference | interpret |
                    pallas) for the prefill and decode-chunk hot paths
    quant:          override ``cfg.quant``; ``"w8a8"`` quantizes the GEMM
                    weights once here (``model.quantize_params``) and serves
                    prefill + decode through the packed int8 kernels
    """

    def __init__(self, cfg: ArchConfig, params, max_len: int = 512, *,
                 max_slots: int = 8, prefill_bucket: int = 32,
                 decode_chunk: int = 8, eos_id: int | None = None,
                 max_queue: int = 1024, kernel_mode: str | None = None,
                 quant: str | None = None):
        if kernel_mode is not None:
            cfg = cfg.with_(kernel_mode=kernel_mode)
        if quant is not None:
            cfg = cfg.with_(quant=quant)
        if cfg.quant == "w8a8":
            params = M.quantize_params(cfg, params)  # idempotent
        self.cfg, self.params = cfg, params
        self.max_slots, self.max_len = max_slots, max_len
        self.prefill_bucket = prefill_bucket
        if prefill_bucket > 1 and any(sp.mixer == "ssm"
                                      for sp in cfg.layer_specs()):
            warnings.warn(
                f"{cfg.name}: SSM layers scan left-pad tokens into their "
                f"recurrent state, so outputs vary with prefill_bucket="
                f"{prefill_bucket}; use prefill_bucket=1 for exact-length "
                f"prompts", stacklevel=2)
        self.decode_chunk = decode_chunk
        self.eos_id = eos_id
        self.max_queue = max_queue
        self.stats = ServeStats()

        self._cache_specs = M.cache_specs(cfg, max_slots, max_len)
        self._caches = jax.tree.map(
            lambda s: jnp.zeros(s.shape, s.dtype or cfg.compute_dtype),
            self._cache_specs, is_leaf=is_spec)
        B = max_slots
        self._cur = np.zeros(B, np.int32)        # next input token per slot
        self._pos = np.zeros(B, np.int32)        # its cache row
        self._start = np.zeros(B, np.int32)      # first live row (pad offset)
        self._remaining = np.zeros(B, np.int32)  # tokens still to emit
        self._temp = np.zeros(B, np.float32)
        self._keys = np.zeros((B, 2), np.uint32)

        self._queue: deque[Request] = deque()
        self._slots: list[_Slot | None] = [None] * B
        self._finished: list[RequestResult] = []
        self._next_rid = 0

        self._decode_fn = jax.jit(self._decode_chunk, donate_argnums=(1,))
        self._prefill_fns: dict[int, Any] = {}

    # ------------------------------------------------------------------
    # compiled pieces
    # ------------------------------------------------------------------

    def _sample(self, logits, temp, keys):
        """Per-slot sampling.  logits: [B,Vp]; temp: [B]; keys: [B,2] u32."""
        lf = logits[:, : self.cfg.vocab_size].astype(jnp.float32)
        greedy = jnp.argmax(lf, -1).astype(jnp.int32)

        def one(key, lg, t):
            return jax.random.categorical(key, lg / jnp.maximum(t, 1e-6))

        sampled = jax.vmap(one)(keys, lf, temp).astype(jnp.int32)
        nxt = jnp.where(temp > 0.0, sampled, greedy)
        keys = jax.vmap(lambda k: jax.random.split(k, 2)[1])(keys)
        return nxt, keys

    def _decode_chunk(self, params, caches, cur, pos, start, remaining, temp,
                      keys):
        """``decode_chunk`` fused decode steps; emits [B, steps] tokens.
        ``start`` holds each slot's left-pad offset (first live cache row) —
        constant across the chunk — so decode attention never reads the pad
        rows the prompt bucketing wrote."""
        cfg = self.cfg

        def body(carry, _):
            caches, cur, pos, remaining, keys = carry
            active = remaining > 0
            logits, caches = M.decode_step(cfg, params, caches, cur[:, None],
                                           pos, start=start)
            nxt, keys = self._sample(logits[:, -1], temp, keys)
            nxt = jnp.where(active, nxt, cur)  # freeze finished slots
            step = active.astype(jnp.int32)
            remaining = remaining - step
            if self.eos_id is not None:
                remaining = jnp.where(active & (nxt == self.eos_id), 0,
                                      remaining)
            return (caches, nxt, pos + step, remaining, keys), nxt

        (caches, cur, pos, remaining, keys), toks = lax.scan(
            body, (caches, cur, pos, remaining, keys), None,
            length=self.decode_chunk)
        return caches, cur, pos, remaining, keys, toks.T  # [B, steps]

    def _write_slot(self, caches, small, slot):
        """Copy a 1-sequence prefill cache into slot `slot` of the big cache,
        zeroing the slot's tail (slot recycling = this overwrite)."""

        def wr(spec, big, sm):
            b_ax = spec.axes.index("batch")
            sm = sm[tuple(slice(0, min(a, b))
                          for a, b in zip(sm.shape, big.shape))]
            block_shape = tuple(1 if i == b_ax else d
                                for i, d in enumerate(big.shape))
            block = jnp.zeros(block_shape, big.dtype)
            block = lax.dynamic_update_slice(block, sm.astype(big.dtype),
                                             (0,) * big.ndim)
            start = tuple(slot if i == b_ax else 0 for i in range(big.ndim))
            return lax.dynamic_update_slice(big, block, start)

        return jax.tree.map(wr, self._cache_specs, caches, small,
                            is_leaf=is_spec)

    def _prefill_fn(self, plen: int):
        """Jitted prefill+insert, one compilation per prompt-length bucket."""
        if plen not in self._prefill_fns:
            cfg = self.cfg

            def fn(params, caches, tokens, slot, start, temp1, key):
                logits, small = M.prefill(cfg, params, {"tokens": tokens},
                                          start=start)
                caches = self._write_slot(caches, small, slot)
                t0, keys1 = self._sample(logits[:, -1], temp1[None],
                                         key[None])
                return caches, t0[0], keys1[0]

            self._prefill_fns[plen] = jax.jit(fn, donate_argnums=(1,))
        return self._prefill_fns[plen]

    # ------------------------------------------------------------------
    # scheduling
    # ------------------------------------------------------------------

    def padded_len(self, prompt_len: int) -> int:
        return max(self.prefill_bucket,
                   round_up(prompt_len, self.prefill_bucket))

    def submit(self, prompt: list[int], max_new: int = 32,
               temperature: float = 0.0, seed: int = 0) -> int:
        """Admit a request; returns its rid.  Raises ``ValueError`` when the
        request can never fit a slot and ``RuntimeError`` on queue overflow
        (backpressure — callers should retry later)."""
        if not prompt:
            raise ValueError("empty prompt")
        if max_new < 1:
            raise ValueError("max_new must be >= 1")
        if self.padded_len(len(prompt)) + max_new > self.max_len:
            raise ValueError(
                f"request needs {self.padded_len(len(prompt)) + max_new} "
                f"cache rows > max_len={self.max_len}")
        if len(self._queue) >= self.max_queue:
            raise RuntimeError("admission queue full")
        rid = self._next_rid
        self._next_rid += 1
        self._queue.append(Request(rid, list(prompt), max_new,
                                   float(temperature), seed,
                                   arrival_s=time.time()))
        return rid

    @property
    def num_active(self) -> int:
        return sum(s is not None for s in self._slots)

    @property
    def num_queued(self) -> int:
        return len(self._queue)

    def _admit(self):
        """Prefill queued requests into free slots."""
        for i in range(self.max_slots):
            if not self._queue or self._slots[i] is not None:
                continue
            req = self._queue.popleft()
            plen = self.padded_len(len(req.prompt))
            start = plen - len(req.prompt)  # left-pad rows [0, start) are dead
            toks = np.zeros((1, plen), np.int32)
            toks[0, start:] = req.prompt  # left-pad
            key = jax.random.PRNGKey(req.seed ^ (req.rid * 0x9E3779B9))
            t0 = time.time()
            self._caches, first, key1 = self._prefill_fn(plen)(
                self.params, self._caches, jnp.asarray(toks), jnp.int32(i),
                jnp.int32(start), jnp.float32(req.temperature), key)
            first = int(first)
            self.stats.prefill_s += time.time() - t0
            self.stats.prefills += 1
            now = time.time()
            self._slots[i] = _Slot(req, emitted=[first], first_token_s=now)
            self._cur[i], self._pos[i] = first, plen
            self._start[i] = start
            self._remaining[i] = req.max_new - 1
            self._temp[i] = req.temperature
            self._keys[i] = np.asarray(key1)
            self.stats.tokens_out += 1
            if self._remaining[i] == 0 or first == self.eos_id:
                self._remaining[i] = 0
                self._retire(i, now)

    def _retire(self, i: int, now: float):
        s = self._slots[i]
        self._finished.append(RequestResult(
            s.req.rid, s.req.prompt, s.emitted, s.req.arrival_s,
            s.first_token_s, now))
        self._slots[i] = None

    def _check_capacity(self):
        """Refuse to decode a slot past its KV capacity.

        Global-attention layers write cache row ``pos``; a write at
        ``pos >= max_len`` is dropped by ``attn_decode`` (never clamped onto
        the last row), so reaching this state means lost context — the
        admission bound (``submit``) should have made it impossible.  Surface
        it as an explicit length error instead of silently degrading.
        """
        steps = np.minimum(self._remaining, self.decode_chunk)
        for i, slot in enumerate(self._slots):
            if slot is not None and self._pos[i] + steps[i] > self.max_len:
                raise RuntimeError(
                    f"slot {i} (rid={slot.req.rid}): decoding {int(steps[i])} "
                    f"steps from pos={int(self._pos[i])} overruns KV capacity "
                    f"max_len={self.max_len}; request length accounting is "
                    f"inconsistent with admission control")

    def step(self) -> list[RequestResult]:
        """One scheduling iteration: admit into free slots, run one compiled
        decode chunk, evict finished sequences.  Returns newly finished."""
        self._admit()
        if self.num_active:
            self._check_capacity()
            before = self._remaining.copy()
            t0 = time.time()
            (self._caches, cur, pos, remaining, keys, toks) = self._decode_fn(
                self.params, self._caches, jnp.asarray(self._cur),
                jnp.asarray(self._pos), jnp.asarray(self._start),
                jnp.asarray(self._remaining), jnp.asarray(self._temp),
                jnp.asarray(self._keys))
            toks = np.asarray(toks)
            self._cur, self._pos = np.array(cur), np.array(pos)
            self._remaining, self._keys = np.array(remaining), np.array(keys)
            self.stats.decode_s += time.time() - t0
            self.stats.chunks += 1
            now = time.time()
            for i, slot in enumerate(self._slots):
                if slot is None:
                    continue
                take = toks[i][: before[i]]
                if self.eos_id is not None:
                    stop = np.nonzero(take == self.eos_id)[0]
                    if stop.size:
                        take = take[: stop[0] + 1]
                slot.emitted.extend(int(t) for t in take)
                self.stats.tokens_out += len(take)
                if self._remaining[i] == 0:
                    self._retire(i, now)
        out, self._finished = self._finished, []
        return out

    def run(self) -> list[RequestResult]:
        """Drive ``step`` until queue and slots drain; returns all results."""
        results = []
        while self._queue or self.num_active:
            results.extend(self.step())
        return results

    # ------------------------------------------------------------------
    # batch-generate compatibility surface (seed API)
    # ------------------------------------------------------------------

    def generate(self, prompts: list[list[int]], max_new: int = 32,
                 temperature: float = 0.0, seed: int = 0):
        """Submit a closed batch and run it to completion.  Returns
        ``(sequences, stats)`` like the seed engine: ``sequences[i]`` is
        prompt + generated for ``prompts[i]``."""
        t_stats = ServeStats(prefill_s=-self.stats.prefill_s,
                             decode_s=-self.stats.decode_s,
                             tokens_out=-self.stats.tokens_out,
                             prefills=-self.stats.prefills,
                             chunks=-self.stats.chunks)
        rids = [self.submit(p, max_new, temperature, seed=seed * 1000003 + i)
                for i, p in enumerate(prompts)]
        by_rid = {r.rid: r for r in self.run()}
        out = [by_rid[r].tokens for r in rids]
        t_stats.prefill_s += self.stats.prefill_s
        t_stats.decode_s += self.stats.decode_s
        t_stats.tokens_out += self.stats.tokens_out
        t_stats.prefills += self.stats.prefills
        t_stats.chunks += self.stats.chunks
        return out, t_stats
