"""Batched serving engine: continuous prefill -> decode with a growable KV
cache, greedy/temperature sampling, and a byte-level tokenizer stub.

This is the inference-side end-to-end driver (deliverable (b)): requests are
batched, prefilled once, then decoded step-by-step; the same ``decode_step``
the dry-run lowers for the decode_32k / long_500k cells.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import model as M
from repro.models.params import ParamSpec, is_spec


def bytes_tokenizer_encode(text: str, vocab: int) -> list[int]:
    return [b % vocab for b in text.encode("utf-8")]


def bytes_tokenizer_decode(tokens) -> str:
    return bytes(int(t) % 256 for t in tokens).decode("utf-8", errors="replace")


def grow_cache(cfg: ArchConfig, caches, new_len: int):
    """Pad every kv_seq cache dim (global-attention / MLA layers) to
    ``new_len``.  Ring-buffer (local) and SSM caches keep their size."""
    specs = M.cache_specs(cfg, 1, new_len)

    def grow(spec, leaf):
        if "kv_seq" not in spec.axes:
            return leaf
        axis = spec.axes.index("kv_seq")
        target = spec.shape[axis]
        pad = target - leaf.shape[axis]
        if pad <= 0:
            return leaf
        widths = [(0, 0)] * leaf.ndim
        widths[axis] = (0, pad)
        return jnp.pad(leaf, widths)

    return jax.tree.map(grow, specs, caches, is_leaf=lambda x: is_spec(x))


@dataclass
class ServeStats:
    prefill_s: float = 0.0
    decode_s: float = 0.0
    tokens_out: int = 0

    @property
    def tokens_per_s(self) -> float:
        return self.tokens_out / self.decode_s if self.decode_s else 0.0


class Engine:
    """Greedy/temperature batched generation over a fixed params pytree."""

    def __init__(self, cfg: ArchConfig, params, max_len: int = 512):
        self.cfg, self.params, self.max_len = cfg, params, max_len
        self._decode = jax.jit(
            lambda p, c, t, pos: M.decode_step(cfg, p, c, t, pos))
        self._prefill = jax.jit(lambda p, b: M.prefill(cfg, p, b))

    def generate(self, prompts: list[list[int]], max_new: int = 32,
                 temperature: float = 0.0, seed: int = 0):
        cfg = self.cfg
        B = len(prompts)
        plen = max(len(p) for p in prompts)
        toks = np.zeros((B, plen), np.int32)
        for i, p in enumerate(prompts):  # left-pad with token 0
            toks[i, plen - len(p):] = p
        stats = ServeStats()

        t0 = time.time()
        logits, caches = self._prefill(self.params, {"tokens": jnp.asarray(toks)})
        caches = grow_cache(cfg, caches, plen + max_new)
        stats.prefill_s = time.time() - t0

        rng = jax.random.PRNGKey(seed)
        out = [list(p) for p in prompts]
        cur = self._sample(logits[:, -1], temperature, rng)
        t0 = time.time()
        for step in range(max_new):
            for i in range(B):
                out[i].append(int(cur[i]))
            logits, caches = self._decode(self.params, caches, cur[:, None],
                                          jnp.int32(plen + step))
            rng, sub = jax.random.split(rng)
            cur = self._sample(logits[:, -1], temperature, sub)
        stats.decode_s = time.time() - t0
        stats.tokens_out = B * max_new
        return out, stats

    def _sample(self, logits, temperature, rng):
        logits = logits[:, : self.cfg.vocab_size].astype(jnp.float32)
        if temperature <= 0.0:
            return jnp.argmax(logits, -1).astype(jnp.int32)
        return jax.random.categorical(rng, logits / temperature).astype(jnp.int32)
