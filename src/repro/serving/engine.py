"""Continuous-batching serving engine over a paged KV cache.

The engine is split into a host-side :class:`Scheduler` (admission control,
the slot state machine, chunk budgeting) and a device-side
:class:`ModelRunner` (the compiled functions and the cache pytree), with
:class:`Engine` as the public facade driving one *unified mixed step* per
tick: up to ``chunk_tokens`` of prompt-chunk work from the prefilling slot
plus one decode token per decoding slot, packed into a single compiled call.
Decode latency stays flat while long prompts stream through in fixed-size
chunks — prefill no longer head-of-line-blocks in-flight decodes.

Slot state machine (``Scheduler``)::

    QUEUED --admit--> PREFILLING(offset) --chunks--> DECODING --eos/limit-->
    RETIRED

Every retirement carries a :class:`FinishReason`: ``STOP``/``LENGTH`` are
the healthy exits; ``DEADLINE`` (per-request budget expired), ``CANCELLED``
(:meth:`Engine.cancel` / :meth:`Engine.close`), ``PREEMPTED`` (evicted under
page pressure with ``preemption="drop"``), ``FAULT`` (non-finite logits —
the slot is isolated, the rest of the batch continues) and ``REJECTED``
(bounded-queue admission refused — never a silent drop) are the degraded
ones.

Admission reserves the request's page need up front — the *full* need
(prompt + max_new rows) by default, or just the prompt rows when
``EngineConfig.preemption`` is enabled (lazy growth: decode rows are
allocated tick by tick, and on pool exhaustion the Scheduler evicts from
the radix tree, then *preempts* the lowest-priority decoding slot — fewest
tokens generated, ties by latest arrival — frees its pages and requeues it;
on re-admission its generated tokens are recomputed via normal chunked
prefill, with radix prefix hits making the recompute cheap, and greedy
outputs stay bit-identical to the never-preempted run).  On
prefix-decomposable models (pure attention) a slot starts at ``offset =
radix prefix hit``; each tick the mixed step advances the oldest prefilling
slot by up to ``chunk_tokens`` prompt rows, writing chunk KV straight
through the page table (``model.chunk_step`` — no dense gather of the
past).  When the chunk completes the prompt, the chunk logits' last valid
row samples the first token and the slot flips to DECODING.  Ticks with no
prefill work run a ``lax.scan`` of ``decode_chunk`` fused decode steps as
before.

Compiled-variant budget: the mixed step compiles once per chunk *buffer*
size — with ``chunk_tokens`` set that is one variant total; unset, the
whole suffix runs as a single chunk in a power-of-two-bucketed buffer
(≤ log2(max_len) variants).  This replaces the per-``(prefix_len,
suffix_len)`` prefill executable cache; the LRU bound
(``Engine.max_prefill_variants``) is kept as a backstop and still governs
the exact-length whole-prompt path used by non-decomposable mixers
(SSM / MLA / cross-attention), which cannot chunk.

Cache layout (``EngineConfig.cache_spec()``, ``CacheLayout.PAGED``): every
attention layer owns a ``[n_pages, page_size, ...]`` page pool allocated up
front via ``model.paged_cache_specs``; each live sequence holds a page
*table* (``[pages_per_seq]`` int32, shared logically across all layers —
pages are allocated in lockstep) mapping logical KV rows to pool pages.
Page 0 is the reserved *trash page*: retired batch rows keep their table
zeroed and ``pos = 0``, so the decode chunk's unconditional writes land
somewhere harmless; the mixed step likewise zeroes the prefilling slot's
row in the decode-side table.

Prefix reuse (``EngineConfig.prefix_cache``): a radix tree over page-sized
token chunks (``serving.paging.RadixCache``) shares full prompt pages
between requests by refcount — a prefix hit of ``s`` tokens skips their
recompute entirely: the slot starts prefilling at ``offset = s`` and the
chunks cover only the suffix.  A partially-matching page is shared
copy-on-write: the new request gets a fresh page, the donor's matched rows
are device-copied, and the chunks overwrite the divergent tail.  A prompt's
full pages are published to the tree when its prefill *completes* (pages
must be fully written before they can be matched), and admission holds
while a slot is prefilling so lookups never race an unpublished prefix.

Fault isolation: every compiled step carries a per-slot non-finite check on
the sampled logits — a poisoned slot (NaN/Inf from bad weights, a flaky
device, or the chaos harness's ``logits.nan`` point) freezes in-graph on
the faulty step and retires with ``FinishReason.FAULT``; slots are
KV-independent, so the rest of the batch is unaffected (MoE joint routing
is the documented exception).  The deterministic chaos harness
(:mod:`repro.serving.chaos`) drives all of these paths from seeded fault
schedules; ``Engine(..., chaos=ChaosInjector(...))`` also reroutes the
engine's clock through the injector so deadline storms are reproducible.

Per-slot determinism: each request carries its own PRNG key and temperature,
and every slot decodes at its own position, so a request's output is
independent of whatever shares the batch with it.  (Exception: MoE layers —
expert capacity is routed jointly over the batch, so under capacity pressure
a request's routing can depend on concurrent traffic, as on any batched MoE
serving system.)
"""
from __future__ import annotations

import functools
import time
import warnings
from collections import OrderedDict, deque
from dataclasses import dataclass, field
from enum import Enum
from typing import Any

import numpy as np

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ArchConfig
from repro.core import round_up
from repro.launch.sharding import activation_mesh, tree_pspecs
from repro.models import model as M
from repro.models.params import is_spec
from repro.serving.chaos import ChaosError, ChaosInjector
from repro.serving.config import CacheSpec, EngineConfig
from repro.serving.paging import (PagePool, PrefixMatch, RadixCache,
                                  check_invariants)


def bytes_tokenizer_encode(text: str, vocab: int) -> list[int]:
    return [b % vocab for b in text.encode("utf-8")]


def bytes_tokenizer_decode(tokens) -> str:
    return bytes(int(t) % 256 for t in tokens).decode("utf-8", errors="replace")


# ---------------------------------------------------------------------------
# Requests / results
# ---------------------------------------------------------------------------

class FinishReason(str, Enum):
    """Why a request retired.  ``STOP``/``LENGTH`` are healthy completions;
    everything else is a degraded exit (see the state machine in the module
    docstring and DESIGN.md §10)."""
    STOP = "stop"            # emitted eos_id
    LENGTH = "length"        # emitted max_new tokens
    DEADLINE = "deadline"    # per-request deadline expired
    CANCELLED = "cancelled"  # Engine.cancel / Engine.close
    PREEMPTED = "preempted"  # evicted under page pressure (preemption="drop")
    FAULT = "fault"          # non-finite logits: slot isolated from the batch
    REJECTED = "rejected"    # bounded queue refused admission at submit


@dataclass
class Request:
    rid: int
    prompt: list[int]
    max_new: int = 32
    temperature: float = 0.0
    seed: int = 0
    arrival_s: float = 0.0
    #: optional wall-clock budget (seconds, relative to arrival); past it the
    #: request retires DEADLINE wherever it is (queued or in flight)
    deadline_s: float | None = None
    # -- preemption/recompute carry-state (engine-internal) ----------------
    #: tokens generated before a preemption; on re-admission the slot
    #: prefills prompt + resume_tokens and continues where it left off
    resume_tokens: list[int] = field(default_factory=list)
    resume_key: Any = None       # PRNG key as of the preemption point
    first_token_s: float | None = None
    token_times: list[float] = field(default_factory=list)
    preemptions: int = 0

    def full_prompt(self) -> list[int]:
        """Rows to prefill: the prompt plus any tokens generated before a
        preemption (recompute path — already-sampled tokens are ordinary
        prefill input the second time around)."""
        return list(self.prompt) + list(self.resume_tokens)


@dataclass
class RequestResult:
    rid: int
    prompt: list[int]
    generated: list[int]
    arrival_s: float
    first_token_s: float
    finish_s: float
    #: wall-clock emission time of each generated token (tick granularity —
    #: tokens emitted by the same compiled call share a timestamp); drives
    #: inter-token-latency percentiles in the serving benchmark
    token_times_s: list[float] = field(default_factory=list)
    finish_reason: FinishReason = FinishReason.LENGTH
    #: backpressure hint on REJECTED results: seconds after which a retry
    #: plausibly finds queue room (estimated from in-flight progress)
    retry_after_s: float | None = None

    @property
    def ok(self) -> bool:
        """True for healthy completions (STOP / LENGTH)."""
        return self.finish_reason in (FinishReason.STOP, FinishReason.LENGTH)

    @property
    def tokens(self) -> list[int]:
        return list(self.prompt) + list(self.generated)

    @property
    def ttft_s(self) -> float:
        return self.first_token_s - self.arrival_s

    @property
    def latency_s(self) -> float:
        return self.finish_s - self.arrival_s

    @property
    def itl_s(self) -> list[float]:
        """Inter-token gaps (seconds) between consecutive emissions."""
        t = self.token_times_s
        return [b - a for a, b in zip(t, t[1:])]


@dataclass
class ServeStats:
    prefill_s: float = 0.0
    decode_s: float = 0.0
    tokens_out: int = 0
    prefills: int = 0
    chunks: int = 0
    mixed_steps: int = 0
    peak_active: int = 0
    prefix_hit_tokens: int = 0
    prefix_lookup_tokens: int = 0
    # resilience counters: one increment per event (see tests/test_resilience)
    preempted: int = 0
    rejected: int = 0
    deadline_expired: int = 0
    cancelled: int = 0
    faults_isolated: int = 0

    @property
    def tokens_per_s(self) -> float:
        return self.tokens_out / self.decode_s if self.decode_s else 0.0

    @property
    def prefix_hit_rate(self) -> float:
        return (self.prefix_hit_tokens / self.prefix_lookup_tokens
                if self.prefix_lookup_tokens else 0.0)


QUEUED = "queued"
PREFILLING = "prefilling"
DECODING = "decoding"


@dataclass
class _Slot:
    req: Request
    emitted: list[int] = field(default_factory=list)
    first_token_s: float = 0.0
    phase: str = DECODING
    offset: int = 0        # prompt rows already in pages (incl. radix hit)
    seq: int = 0           # admission order (FIFO chunk scheduling)
    key: Any = None        # request PRNG key until the first sample commits
    token_times: list[float] = field(default_factory=list)


_LEGACY_KWARGS = ("max_len", "max_slots", "prefill_bucket", "decode_chunk",
                  "eos_id", "max_queue", "kernel_mode", "quant")


# ---------------------------------------------------------------------------
# ModelRunner: the compiled pieces + the cache pytree
# ---------------------------------------------------------------------------

class ModelRunner:
    """Owns the device state (params, paged cache pools) and every compiled
    function the engine calls: the fused decode chunk, the unified mixed
    step (one compiled variant per chunk-buffer size), the exact-length
    whole-prompt prefill for non-decomposable mixers, and the COW page copy.
    Executables live in one LRU (`fns`) bounded by the caller-supplied
    variant limit."""

    def __init__(self, cfg: ArchConfig, params, config: EngineConfig):
        self.cfg = cfg
        self.page_size = config.page_size
        self.decode_chunk = config.decode_chunk
        self.eos_id = config.eos_id
        self.vocab = cfg.vocab_size
        # mesh-sharded serving: place params with the logical-axis TP rules
        # and every KV pool over its kv_heads axis (page tables stay
        # replicated host-side numpy — the Scheduler is device-agnostic)
        self.mesh = (config.mesh.build()
                     if config.mesh is not None and config.mesh.size > 1
                     else None)
        if self.mesh is not None:
            params = M.shard_params(cfg, params, self.mesh)
        self.params = params
        self.cache_specs = M.paged_cache_specs(cfg, config.max_batch,
                                               config.n_pages,
                                               config.page_size)
        self.caches = jax.tree.map(
            lambda s: jnp.zeros(s.shape, s.dtype or cfg.compute_dtype),
            self.cache_specs, is_leaf=is_spec)
        if self.mesh is not None:
            self.caches = jax.tree.map(
                jax.device_put, self.caches,
                tree_pspecs(self.cache_specs, self.mesh))
        self.decode_fn = jax.jit(self._traced(self._decode_chunk),
                                 donate_argnums=(1,))
        self.copy_fn = jax.jit(self._traced(self._copy_page),
                               donate_argnums=(0,))
        self.fns: OrderedDict[tuple, Any] = OrderedDict()

    def _traced(self, fn):
        """Trace-time mesh context: the model's ``constrain`` calls (and the
        Pallas ``shard_map`` wrappers) only see the mesh if it is set while
        jit *traces* the function, not when the executable is called."""
        if self.mesh is None:
            return fn

        @functools.wraps(fn)
        def wrapped(*args):
            with activation_mesh(self.mesh):
                return fn(*args)

        return wrapped

    # -- sampling / decode ------------------------------------------------

    def _sample_lf(self, lf, temp, keys):
        """Per-slot sampling from f32 vocab logits.  lf: [B,V]; temp: [B];
        keys: [B,2] u32."""
        greedy = jnp.argmax(lf, -1).astype(jnp.int32)

        def one(key, lg, t):
            return jax.random.categorical(key, lg / jnp.maximum(t, 1e-6))

        sampled = jax.vmap(one)(keys, lf, temp).astype(jnp.int32)
        nxt = jnp.where(temp > 0.0, sampled, greedy)
        keys = jax.vmap(lambda k: jax.random.split(k, 2)[1])(keys)
        return nxt, keys

    def _sample(self, logits, temp, keys):
        """Per-slot sampling.  logits: [B,Vp]; temp: [B]; keys: [B,2] u32."""
        return self._sample_lf(logits[:, : self.vocab].astype(jnp.float32),
                               temp, keys)

    def _dec_body(self, params, pages, temp, nanmask):
        """One decode step as a scan body — shared verbatim between the
        decode-only chunk and the mixed step, so a token's math does not
        depend on which tick shape produced it.

        Fault isolation happens here, in-graph: a live slot whose logits go
        non-finite (``nanmask`` injects NaN for the chaos harness) freezes
        immediately — no token is taken, ``remaining`` drops to 0 — and the
        per-step ``ok`` flag tells the host which step went bad.  Slots that
        are already frozen decode trash-page garbage by design, so only
        *active* slots can fault."""
        cfg = self.cfg

        def body(carry, _):
            caches, cur, pos, remaining, keys = carry
            active = remaining > 0
            logits, caches = M.decode_step(cfg, params, caches, cur[:, None],
                                           pos, pages=pages)
            lf = logits[:, -1, : self.vocab].astype(jnp.float32)
            lf = jnp.where(nanmask[:, None], jnp.nan, lf)
            finite = jnp.all(jnp.isfinite(lf), -1)
            nxt, keys = self._sample_lf(lf, temp, keys)
            ok = finite | ~active      # a frozen slot cannot fault
            nxt = jnp.where(active & finite, nxt, cur)
            step = (active & finite).astype(jnp.int32)
            remaining = jnp.where(ok, remaining - step, 0)
            if self.eos_id is not None:
                remaining = jnp.where(active & finite & (nxt == self.eos_id),
                                      0, remaining)
            return (caches, nxt, pos + step, remaining, keys), (nxt, ok)

        return body

    def _decode_chunk(self, params, caches, pages, cur, pos, remaining, temp,
                      keys, nanmask):
        """``decode_chunk`` fused decode steps; emits [B, steps] tokens plus
        the matching [B, steps] per-step fault flags.  ``pages`` [B, npp] is
        constant across the chunk (each request's page need for the chunk is
        reserved before the tick); finished slots freeze — their table is
        re-pointed at the trash page on retirement, so the chunk's
        unconditional KV writes can never corrupt a reallocated page."""
        (caches, cur, pos, remaining, keys), (toks, oks) = lax.scan(
            self._dec_body(params, pages, temp, nanmask),
            (caches, cur, pos, remaining, keys), None,
            length=self.decode_chunk)
        return caches, cur, pos, remaining, keys, toks.T, oks.T  # [B, steps]

    # -- the unified mixed step -------------------------------------------

    def _mixed(self, params, caches, chunk_toks, chunk_pages, chunk_past,
               chunk_len, chunk_temp, chunk_key, chunk_nan, dec_pages, cur,
               pos, remaining, temp, keys, nanmask):
        """One engine tick: a prompt chunk for the prefilling slot plus one
        decode step for every decoding slot, in a single compiled call.

        chunk_toks [1, C] (``chunk_len`` valid rows at absolute positions
        ``chunk_past + i``), chunk_pages [1, npp].  ``dec_pages`` is the
        batch page table with the prefilling slot's row zeroed, so the
        decode pass's unconditional write for that (frozen) row lands on the
        trash page.  The chunk's sampled token/key only matter on the tick
        the chunk completes the prompt — the host discards them otherwise.
        ``chunk_ok`` is the chunk-side fault flag (the chunk logits are the
        last *valid* row, so non-finite means the prefilling slot is
        poisoned regardless of which tick it is)."""
        logits, caches = M.chunk_step(self.cfg, params, caches, chunk_toks,
                                      chunk_pages, chunk_past, chunk_len)
        lf = logits[:, -1, : self.vocab].astype(jnp.float32)
        lf = jnp.where(chunk_nan, jnp.nan, lf)
        chunk_ok = jnp.all(jnp.isfinite(lf))
        tok0, key0 = self._sample_lf(lf, chunk_temp[None], chunk_key[None])
        (caches, cur, pos, remaining, keys), (toks, oks) = lax.scan(
            self._dec_body(params, dec_pages, temp, nanmask),
            (caches, cur, pos, remaining, keys), None, length=1)
        return (caches, tok0[0], key0[0], chunk_ok, cur, pos, remaining,
                keys, toks.T, oks.T)

    def mixed_fn(self, C: int, limit: int):
        """The mixed-step executable for chunk-buffer size ``C`` (the only
        shape degree of freedom — chunk offset/length are traced scalars)."""
        return self._cached(
            ("mixed", C),
            lambda: jax.jit(self._traced(self._mixed), donate_argnums=(1,)),
            limit)

    # -- exact-length whole-prompt prefill (non-decomposable mixers) ------

    def _flat_rows(self, table, first: int, n: int):
        """Pool-row indices of logical rows ``[first, first + n)``."""
        j = jnp.arange(n, dtype=jnp.int32) + first
        return table[j // self.page_size] * self.page_size + j % self.page_size

    def _scatter_new(self, caches, small, table, slot, n: int):
        """Write a whole-prompt prefill's outputs into the big cache: kv_seq
        leaves scatter their ``n`` rows to logical rows ``[0, n)`` through
        the page table; stateful leaves (SSM state, cross image-KV)
        overwrite batch row ``slot``."""
        rows = self._flat_rows(table, 0, n)

        def w(spec, pool, sm):
            if "kv_seq" in spec.axes:
                R, P, ps = pool.shape[0], pool.shape[1], pool.shape[2]
                flat = pool.reshape(R, P * ps, *pool.shape[3:])
                flat = flat.at[:, rows].set(sm[:, 0].astype(pool.dtype))
                return flat.reshape(pool.shape)
            return pool.at[:, slot].set(sm[:, 0].astype(pool.dtype))

        return jax.tree.map(w, self.cache_specs, caches, small,
                            is_leaf=is_spec)

    def _whole_prefill(self, n: int, params, caches, tokens, table, slot,
                       temp1, rkey):
        """Exact-length whole-prompt prefill + cache insert (traceable —
        ``repro.analysis`` walks this jaxpr; ``whole_prefill_fn`` jits it).
        ``ok`` is the fault flag over the sampled logits row."""
        logits, small = M.prefill(self.cfg, params, {"tokens": tokens},
                                  full_kv=True)
        caches = self._scatter_new(caches, small, table, slot, n)
        lf = logits[:, -1, : self.vocab].astype(jnp.float32)
        ok = jnp.all(jnp.isfinite(lf))
        t0, key1 = self._sample_lf(lf, temp1[None], rkey[None])
        return caches, t0[0], key1[0], ok

    def whole_prefill_fn(self, n: int, limit: int):
        """Jitted exact-length prefill + cache insert for mixers whose
        prefill is not prefix-decomposable (SSM / MLA / cross-attention —
        they cannot run as chunks over a paged past).  One compilation per
        prompt length, LRU-bounded like the mixed variants."""
        return self._cached(
            ("whole", n),
            lambda: jax.jit(
                self._traced(functools.partial(self._whole_prefill, n)),
                donate_argnums=(1,)),
            limit)

    def _cached(self, key, build, limit: int):
        fn = self.fns.pop(key, None)
        if fn is None:
            fn = build()
        self.fns[key] = fn  # (re)insert as most recently used
        while len(self.fns) > limit:
            self.fns.popitem(last=False)
        return fn

    # -- COW page copy ----------------------------------------------------

    def _copy_page(self, caches, src, dst):
        """Device copy page ``src`` -> ``dst`` in every KV pool (the COW half
        of a partial-page prefix share; the chunk prefill then overwrites
        the divergent tail rows of ``dst``)."""

        def cp(spec, pool):
            if "kv_seq" not in spec.axes:
                return pool
            return pool.at[:, dst].set(pool[:, src])

        return jax.tree.map(cp, self.cache_specs, caches, is_leaf=is_spec)


# ---------------------------------------------------------------------------
# Scheduler: admission, chunk budgeting, slot state machine
# ---------------------------------------------------------------------------

class Scheduler:
    """Host-side request bookkeeping: the bounded admission queue, per-slot
    numpy state (page tables, positions, budgets, PRNG keys), page/radix
    accounting, and the QUEUED → PREFILLING → DECODING → RETIRED state
    machine (with the degraded exits — DEADLINE / CANCELLED / PREEMPTED /
    FAULT — layered on).  It decides *what* runs each tick (`next_chunk`);
    the :class:`ModelRunner` decides *how*."""

    def __init__(self, config: EngineConfig, decomposable: bool,
                 clock=time.time):
        B = config.max_batch
        self.config = config
        self.clock = clock
        self.page_size = config.page_size
        self.max_batch = B
        self.npp = config.cache_spec().pages_per_seq
        self.pool = PagePool(config.n_pages)
        # preemption implies lazy page reservation: admission takes only the
        # prompt's pages and decode rows grow tick by tick, so the pool can
        # oversubscribe and preemption resolves the pressure
        self.lazy = config.preemption != "off"
        # Chunked prefill (and prefix reuse) require prefill to decompose
        # over the prompt: pure attention (incl. sliding-window) qualifies;
        # SSM mixers scan state across the whole prompt, cross-attn prefill
        # depends on the image, and this MLA prefill recomputes absorbed
        # latents — all excluded, and served by exact whole-prompt prefill.
        self.chunked = decomposable
        self.radix: RadixCache | None = (
            RadixCache(config.page_size, self.pool)
            if (config.prefix_cache and decomposable) else None)

        self.pages = np.zeros((B, self.npp), np.int32)  # 0 == trash page
        self.owned: list[list[int]] = [[] for _ in range(B)]  # page refs
        self.cur = np.zeros(B, np.int32)        # next input token per slot
        self.pos = np.zeros(B, np.int32)        # its logical cache row
        self.limit = np.zeros(B, np.int32)      # reserved rows (plen+max_new)
        self.remaining = np.zeros(B, np.int32)  # tokens still to emit
        self.temp = np.zeros(B, np.float32)
        self.keys = np.zeros((B, 2), np.uint32)

        self.queue: deque[Request] = deque()
        self.slots: list[_Slot | None] = [None] * B
        self.finished: list[RequestResult] = []
        self._seq = 0

    @property
    def num_active(self) -> int:
        return sum(s is not None for s in self.slots)

    @property
    def num_queued(self) -> int:
        return len(self.queue)

    def pages_needed(self, prompt_len: int, max_new: int) -> int:
        return -(-(prompt_len + max_new) // self.page_size)

    def prefilling_slot(self) -> int | None:
        """Index of the slot currently streaming its prompt (at most one:
        admission holds while a prefill is in flight)."""
        cands = [i for i, s in enumerate(self.slots)
                 if s is not None and s.phase == PREFILLING]
        if not cands:
            return None
        return min(cands, key=lambda j: self.slots[j].seq)

    def next_chunk(self) -> tuple[int, int] | None:
        """(slot, n): the next prompt chunk to run — up to ``chunk_tokens``
        rows of the oldest prefilling slot (the whole remaining suffix when
        chunking is off)."""
        i = self.prefilling_slot()
        if i is None:
            return None
        slot = self.slots[i]
        left = len(slot.req.full_prompt()) - slot.offset
        ct = self.config.chunk_tokens
        return i, (left if ct is None else min(ct, left))

    def _ensure_free_pages(self, fresh_needed: int) -> bool:
        """True when the pool can supply ``fresh_needed`` pages, evicting
        radix-cached pages only if eviction actually gets there — a request
        that stays blocked must not cost the tree pages it cannot use."""
        if self.pool.num_free >= fresh_needed:
            return True
        if self.radix is None:
            return False
        if self.pool.num_free + self.radix.num_evictable() < fresh_needed:
            return False
        self.radix.evict(fresh_needed)
        return True

    # -- degraded exits ---------------------------------------------------

    def queue_result(self, req: Request, now: float,
                     reason: FinishReason) -> RequestResult:
        """Result for a request that exits without (re)gaining a slot —
        rejected / expired / cancelled while queued.  Tokens generated
        before a preemption are preserved (never silently dropped)."""
        return RequestResult(
            req.rid, req.prompt, list(req.resume_tokens), req.arrival_s,
            req.first_token_s if req.first_token_s is not None else now,
            now, token_times_s=list(req.token_times), finish_reason=reason)

    def expire(self, now: float, stats: ServeStats):
        """Retire every request whose deadline has passed — queued requests
        exit empty-handed; in-flight slots keep their partial output."""
        for req in [r for r in self.queue
                    if r.deadline_s is not None
                    and now - r.arrival_s > r.deadline_s]:
            self.queue.remove(req)
            stats.deadline_expired += 1
            self.finished.append(
                self.queue_result(req, now, FinishReason.DEADLINE))
        for i, slot in enumerate(self.slots):
            if (slot is not None and slot.req.deadline_s is not None
                    and now - slot.req.arrival_s > slot.req.deadline_s):
                stats.deadline_expired += 1
                self.retire(i, now, FinishReason.DEADLINE)

    def cancel(self, rid: int, now: float, stats: ServeStats) -> bool:
        """Cancel a request wherever it is; False if unknown/finished."""
        for req in self.queue:
            if req.rid == rid:
                self.queue.remove(req)
                stats.cancelled += 1
                self.finished.append(
                    self.queue_result(req, now, FinishReason.CANCELLED))
                return True
        for i, slot in enumerate(self.slots):
            if slot is not None and slot.req.rid == rid:
                stats.cancelled += 1
                self.retire(i, now, FinishReason.CANCELLED)
                return True
        return False

    def _pick_victim(self) -> int | None:
        """Preemption victim policy: the lowest-priority DECODING slot —
        fewest tokens generated, ties broken by latest arrival (newest
        request yields first).  The slot asking for pages is a candidate
        like any other: when it is itself the lowest-priority slot it
        yields (self-preempts) rather than stealing from above."""
        cands = [i for i, s in enumerate(self.slots)
                 if s is not None and s.phase == DECODING]
        if not cands:
            return None
        return min(cands, key=lambda j: (len(self.slots[j].emitted),
                                         -self.slots[j].req.arrival_s,
                                         -self.slots[j].seq))

    def preempt(self, i: int, stats: ServeStats):
        """Evict slot ``i``: free its pages and either requeue it for
        recompute (``preemption="recompute"`` — generated tokens re-enter as
        prefill input, so greedy output stays bit-identical) or retire it
        with its partial output (``preemption="drop"`` — load shedding)."""
        now = self.clock()
        slot = self.slots[i]
        req = slot.req
        stats.preempted += 1
        if self.config.preemption == "drop" \
                or len(slot.emitted) >= req.max_new:
            self.retire(i, now, FinishReason.PREEMPTED)
            return
        req.resume_tokens = list(slot.emitted)
        req.resume_key = np.array(self.keys[i])
        req.first_token_s = slot.first_token_s
        req.token_times = list(slot.token_times)
        req.preemptions += 1
        self.slots[i] = None
        for pid in self.owned[i]:
            self.pool.decref(pid)
        self.owned[i] = []
        self.pages[i] = 0
        self.pos[i] = self.cur[i] = self.remaining[i] = 0
        self.queue.appendleft(req)  # preempted requests keep queue priority

    def ensure_rows(self, i: int, rows: int, stats: ServeStats) -> bool:
        """Lazy page growth: make slot ``i``'s table cover ``rows`` logical
        rows, allocating pages on demand.  On exhaustion: radix-evict, then
        preempt the lowest-priority decoding slot — ``i`` itself when it is
        the lowest (requeue — never raise).  Returns False when ``i`` no
        longer holds its slot."""
        need = -(-rows // self.page_size)
        tries = 0
        while len(self.owned[i]) < need:
            pid = self.pool.alloc()
            if pid is not None:
                self.pages[i][len(self.owned[i])] = pid
                self.owned[i].append(pid)
                tries = 0
                continue
            tries += 1
            if tries <= 2 and self._ensure_free_pages(1):
                continue  # radix evicted / transient alloc fault: retry
            victim = self._pick_victim()
            if victim is None or victim == i:
                self.preempt(i, stats)  # i is lowest-priority: yield
                return False
            self.preempt(victim, stats)
            tries = 0
        return True

    def grow_for_decode(self, steps_bound: int, stats: ServeStats):
        """Before a tick, grow every decoding slot's page table to cover the
        rows the next ``steps_bound`` decode steps will write.  Growth runs
        in descending priority order, so under pressure the high-priority
        slots claim pages first and the victim policy preempts from the
        bottom."""
        order = sorted(
            [i for i, s in enumerate(self.slots)
             if s is not None and s.phase == DECODING],
            key=lambda j: (-len(self.slots[j].emitted),
                           self.slots[j].req.arrival_s, self.slots[j].seq))
        for i in order:
            if self.slots[i] is None:
                continue  # preempted as a victim earlier in this pass
            steps = min(int(self.remaining[i]), steps_bound)
            if steps:
                self.ensure_rows(i, int(self.pos[i]) + steps, stats)

    # -- admission --------------------------------------------------------

    def admit(self, runner: ModelRunner, stats: ServeStats,
              variant_limit: int):
        """Move queued requests into free batch rows.  FIFO with
        head-of-line blocking: when the head request's page need cannot be
        met even after radix eviction, admission stops until retirements
        free pages (no starvation of large requests).  With preemption
        enabled, admission reserves only the prompt's pages (decode rows
        grow lazily).  On chunked (prefix-decomposable) models a newly
        admitted slot enters PREFILLING and admission holds until its
        prefill completes — lookups must never match pages that are not
        fully written and published; non-decomposable models prefill whole
        prompts inline.  A preempted request re-enters here: its prompt plus
        already-generated tokens prefill as one sequence (radix hits make
        that cheap), and its saved PRNG key resumes the sample chain."""
        free_rows = [i for i in range(self.max_batch)
                     if self.slots[i] is None]
        while self.queue and free_rows:
            if self.chunked and self.prefilling_slot() is not None:
                break
            req = self.queue[0]
            full = req.full_prompt()
            plen = len(full)
            new_budget = req.max_new - len(req.resume_tokens)
            need = (self.pages_needed(plen, 0) if self.lazy
                    else self.pages_needed(plen, new_budget))
            if self.radix is not None:
                ht, lt = self.radix.hit_tokens, self.radix.lookup_tokens
                m = self.radix.match(full, max_match=plen - 1)
            else:
                m = PrefixMatch()
            fresh_needed = need - len(m.full_pages)
            # Pin every matched page (and the COW donor) *before* eviction
            # can run: tree-only pages (refcount 1) are legitimate LRU
            # victims, and an unpinned match could be freed by the very
            # evict() that makes room for its own suffix — the page table
            # would then point at a page the pool hands to someone else.
            pinned = list(m.full_pages)
            if m.partial is not None:
                pinned.append(m.partial[0])
            for pid in pinned:
                self.pool.incref(pid)
            ok = self._ensure_free_pages(fresh_needed)
            if not ok and m.partial is not None:
                # The pinned donor may itself be the one page eviction is
                # short of (a request sized to the whole pool); retry with
                # the copy-on-write share dropped rather than deadlock.
                self.pool.decref(pinned.pop())
                self.radix.hit_tokens -= m.partial[1]
                m.partial = None
                m.tokens = len(m.full_pages) * self.page_size
                ok = self._ensure_free_pages(fresh_needed)
            fresh: list[int] = []
            if ok:
                for _ in range(fresh_needed):
                    pid = self.pool.alloc()
                    if pid is None:  # transient alloc fault (chaos)
                        break
                    fresh.append(pid)
                ok = len(fresh) == fresh_needed
            if not ok:
                for pid in fresh:
                    self.pool.decref(pid)
                for pid in pinned:
                    self.pool.decref(pid)
                if self.radix is not None:  # blocked: don't count the lookup
                    self.radix.hit_tokens = ht
                    self.radix.lookup_tokens = lt
                break
            self.queue.popleft()
            i = free_rows.pop(0)
            s = m.tokens  # cached prefix length (<= plen - 1)
            shared = list(m.full_pages)  # pins transfer to slot ownership
            table = np.zeros(self.npp, np.int32)
            table[: len(shared)] = shared
            table[len(shared): len(shared) + len(fresh)] = fresh
            if m.partial is not None:  # copy-on-write share of a partial page
                donor, _rows = m.partial
                runner.caches = runner.copy_fn(runner.caches,
                                               jnp.int32(donor),
                                               jnp.int32(fresh[0]))
                self.pool.decref(donor)  # COW copy done: release the pin

            key = (np.asarray(req.resume_key) if req.resume_key is not None
                   else np.asarray(
                       jax.random.PRNGKey(req.seed ^ (req.rid * 0x9E3779B9))))
            self.pages[i] = table
            self.owned[i] = shared + fresh
            self.limit[i] = plen + new_budget
            self.temp[i] = req.temperature
            if self.chunked:
                # slot enters PREFILLING at the radix offset; the engine's
                # mixed ticks stream the suffix through in chunks
                slot = _Slot(req, emitted=list(req.resume_tokens),
                             first_token_s=req.first_token_s or 0.0,
                             phase=PREFILLING, offset=s, seq=self._seq,
                             key=key, token_times=list(req.token_times))
                self._seq += 1
                self.slots[i] = slot
                self.cur[i] = self.pos[i] = self.remaining[i] = 0
                break  # hold admission until this prefill completes
            # non-decomposable: exact-length whole-prompt prefill, inline
            assert s == 0 and m.partial is None
            toks = np.asarray(full, np.int32)[None]
            t0 = time.time()
            runner.caches, first, key1, pok = runner.whole_prefill_fn(
                plen, variant_limit)(
                    runner.params, runner.caches, jnp.asarray(toks),
                    jnp.asarray(table), jnp.int32(i),
                    jnp.float32(req.temperature), jnp.asarray(key))
            first = int(first)
            stats.prefill_s += time.time() - t0
            stats.prefills += 1
            now = self.clock()
            slot = _Slot(req, emitted=list(req.resume_tokens),
                         first_token_s=req.first_token_s or now,
                         phase=DECODING, seq=self._seq,
                         token_times=list(req.token_times))
            self._seq += 1
            self.slots[i] = slot
            if not bool(pok):  # poisoned prefill: isolate this request
                stats.faults_isolated += 1
                self.retire(i, now, FinishReason.FAULT)
                free_rows.append(i)
                continue
            slot.emitted.append(first)
            slot.token_times.append(now)
            self.cur[i], self.pos[i] = first, plen
            self.remaining[i] = req.max_new - len(slot.emitted)
            self.keys[i] = np.asarray(key1)
            stats.tokens_out += 1
            if self.remaining[i] == 0 or first == self.config.eos_id:
                self.remaining[i] = 0
                self.retire(i, now)
                free_rows.append(i)

    def commit_prefill(self, i: int, first: int, key1, now: float,
                       stats: ServeStats) -> bool:
        """A chunk just completed slot ``i``'s prompt: sample committed,
        slot flips to DECODING (or retires immediately on eos / max_new=1).
        Publishes the prompt's full pages to the radix tree — only now are
        they fully written and safe to match.  Returns True if retired."""
        slot = self.slots[i]
        req = slot.req
        full = req.full_prompt()
        plen = len(full)
        if self.radix is not None:
            fp = plen // self.page_size
            self.radix.insert(full[: fp * self.page_size],
                              [int(self.pages[i][j]) for j in range(fp)])
        slot.phase = DECODING
        slot.emitted = list(req.resume_tokens) + [first]
        slot.first_token_s = (req.first_token_s
                              if req.first_token_s is not None else now)
        slot.token_times = list(req.token_times) + [now]
        slot.key = None
        self.cur[i], self.pos[i] = first, plen
        self.remaining[i] = req.max_new - len(slot.emitted)
        self.keys[i] = np.asarray(key1)
        stats.prefills += 1
        stats.tokens_out += 1
        if self.remaining[i] == 0 or first == self.config.eos_id:
            self.remaining[i] = 0
            self.retire(i, now)
            return True
        return False

    def retire(self, i: int, now: float,
               reason: FinishReason | None = None):
        s = self.slots[i]
        if reason is None:
            reason = (FinishReason.STOP
                      if (self.config.eos_id is not None and s.emitted
                          and s.emitted[-1] == self.config.eos_id)
                      else FinishReason.LENGTH)
        self.finished.append(RequestResult(
            s.req.rid, s.req.prompt, s.emitted, s.req.arrival_s,
            s.first_token_s, now, token_times_s=list(s.token_times),
            finish_reason=reason))
        self.slots[i] = None
        for pid in self.owned[i]:
            self.pool.decref(pid)  # radix-held pages survive at rc >= 1
        self.owned[i] = []
        self.pages[i] = 0  # trash page: frozen-row writes land harmlessly
        self.pos[i] = 0
        self.cur[i] = 0
        self.remaining[i] = 0

    def check_capacity(self, steps_bound: int,
                       stats: ServeStats | None = None):
        """Refuse to decode a slot past its reserved rows.

        Rows beyond the reservation would route to the trash page (never
        corrupt another sequence), but reaching that state means silently
        lost context — the admission bound (``submit``) should have made it
        impossible.  With preemption enabled the engine degrades instead of
        raising: the slot is preempted (requeue or drop), which re-derives
        its accounting from scratch on re-admission.
        """
        for i, slot in enumerate(self.slots):
            if slot is None or slot.phase != DECODING:
                continue
            steps = min(int(self.remaining[i]), steps_bound)
            if self.pos[i] + steps <= self.limit[i]:
                continue
            if self.lazy and stats is not None:
                self.preempt(i, stats)
                continue
            raise RuntimeError(
                f"slot {i} (rid={slot.req.rid}): decoding {steps} "
                f"steps from pos={int(self.pos[i])} overruns KV capacity "
                f"{int(self.limit[i])} rows; request length accounting "
                f"is inconsistent with admission control")


# ---------------------------------------------------------------------------
# Engine
# ---------------------------------------------------------------------------

class Engine:
    """Continuous-batching engine over a fixed params pytree.

    Construct with an :class:`~repro.serving.config.EngineConfig`::

        eng = Engine(cfg, params, EngineConfig(max_batch=8, max_len=512,
                                               page_size=64,
                                               chunk_tokens=32))

    The pre-paging keyword spelling (``max_slots=``, ``prefill_bucket=``,
    ...) still works through a ``DeprecationWarning`` shim: ``max_slots``
    maps to ``max_batch``, ``prefill_bucket`` is ignored (prefill is
    exact-length now), and the default page budget reproduces the legacy
    ``max_slots * max_len`` row capacity.

    Resilience surface: per-request deadlines (``submit(deadline_s=...)``),
    :meth:`cancel`, :meth:`close` (also the context-manager exit), bounded-
    queue rejection with a ``retry_after_s`` hint, and — behind
    ``EngineConfig(preemption=...)`` — page-pool preemption with recompute.
    Pass ``chaos=ChaosInjector(...)`` to drive the fault points
    deterministically (the injector also becomes the engine's clock).
    """

    #: Bound on cached executables in the runner's LRU: mixed-step variants
    #: (one per chunk-buffer size — a handful at most) plus exact-length
    #: whole-prompt prefills for non-decomposable mixers (one per prompt
    #: length — the reason the bound exists).
    max_prefill_variants: int = 32

    def __init__(self, cfg: ArchConfig, params,
                 config: EngineConfig | int | None = None,
                 chaos: ChaosInjector | None = None, **legacy):
        if isinstance(config, int):  # legacy positional: Engine(cfg, p, 512)
            legacy["max_len"] = config
            config = None
        if legacy:
            if config is not None:
                raise TypeError("pass either an EngineConfig or legacy "
                                "keyword arguments, not both")
            unknown = set(legacy) - set(_LEGACY_KWARGS)
            if unknown:
                raise TypeError(f"unknown Engine arguments: {sorted(unknown)}")
            warnings.warn(
                "Engine(max_len=..., max_slots=..., ...) is deprecated; pass "
                "EngineConfig (max_slots -> max_batch; prefill_bucket is "
                "gone — prefill is exact-length on the paged cache)",
                DeprecationWarning, stacklevel=2)
            legacy.pop("prefill_bucket", None)
            legacy["max_batch"] = legacy.pop("max_slots", 8)
            config = EngineConfig(**legacy)
        if config is None:
            config = EngineConfig()

        if config.kernel_mode is not None:
            cfg = cfg.with_(kernel_mode=config.kernel_mode)
        if config.quant is not None:
            cfg = cfg.with_(quant=config.quant)
        if cfg.quant == "w8a8":
            params = M.quantize_params(cfg, params)  # idempotent
        if config.mesh is not None and config.mesh.model > 1 \
                and cfg.num_experts and cfg.num_experts % config.mesh.model == 0:
            # expert-parallel decode: route tokens across the model axis via
            # the moe_specs/_moe_expert_block manual-axis path (each device
            # holds E/tp experts; the dispatch/combine gathers stay local
            # and one f32 psum merges the partial outputs)
            cfg = cfg.with_(moe_shard_map=True)
        self.cfg, self.params = cfg, params
        self.config = config
        self.cache_spec: CacheSpec = config.cache_spec()
        self.decode_chunk = config.decode_chunk
        self.chunk_tokens = config.chunk_tokens
        self.eos_id = config.eos_id
        self.max_queue = config.max_queue
        self.max_batch = config.max_batch
        self.max_len = config.max_len
        self.page_size = config.page_size
        self.npp = self.cache_spec.pages_per_seq
        self.stats = ServeStats()
        self.chaos = chaos
        self._closed = False

        decomposable = (not cfg.use_mla and
                        all(sp.mixer not in ("ssm", "cross")
                            for sp in cfg.layer_specs()))
        self.runner = ModelRunner(cfg, self.params, config)
        self.sched = Scheduler(config, decomposable, clock=self._now)
        if chaos is not None:
            self.sched.pool.fault = lambda: chaos.fire("pool.alloc")
        self._next_rid = 0

    def _now(self) -> float:
        """The engine clock — the chaos injector's skewed clock when one is
        attached (deterministic deadline storms), wall time otherwise."""
        return self.chaos.now() if self.chaos is not None else time.time()

    # -- state shared with the scheduler/runner (test-visible surface) ----

    @property
    def pool(self) -> PagePool:
        return self.sched.pool

    @property
    def radix(self) -> RadixCache | None:
        return self.sched.radix

    @property
    def num_active(self) -> int:
        return self.sched.num_active

    @property
    def num_queued(self) -> int:
        return self.sched.num_queued

    @property
    def prefix_hit_rate(self) -> float:
        return self.radix.hit_rate if self.radix else 0.0

    @property
    def _caches(self):
        return self.runner.caches

    @property
    def _prefill_fns(self):
        return self.runner.fns

    @property
    def _pages(self):
        return self.sched.pages

    @property
    def _remaining(self):
        return self.sched.remaining

    @property
    def _slots(self):
        return self.sched.slots

    def pages_needed(self, prompt_len: int, max_new: int) -> int:
        return self.sched.pages_needed(prompt_len, max_new)

    # -- admission --------------------------------------------------------

    def submit(self, prompt: list[int], max_new: int = 32,
               temperature: float = 0.0, seed: int = 0,
               deadline_s: float | None = None) -> int:
        """Admit a request; returns its rid.  Raises ``ValueError`` on
        malformed input or a request that can never fit (rows or pages —
        rejecting at submit time keeps an impossible request from
        head-of-line-blocking the queue forever).  Queue overflow does not
        raise: the request finishes immediately as ``REJECTED`` with a
        ``retry_after_s`` backpressure hint (collect it from ``step()`` /
        ``run()`` like any other result).  ``deadline_s`` (seconds from
        now; default ``EngineConfig.deadline_s``) bounds the request's
        wall-clock life across queueing and execution."""
        if self._closed:
            raise RuntimeError("engine is closed; create a new Engine")
        prompt = list(prompt)
        if not prompt:
            raise ValueError("empty prompt: a request must carry at least "
                             "one prompt token")
        if not all(isinstance(t, (int, np.integer)) and 0 <= t < self.cfg.vocab_size
                   for t in prompt):
            raise ValueError(f"prompt tokens must be ints in "
                             f"[0, {self.cfg.vocab_size})")
        if not isinstance(max_new, (int, np.integer)) or max_new < 1:
            raise ValueError(f"max_new={max_new!r} must be an int >= 1")
        if temperature < 0.0:
            raise ValueError(f"temperature={temperature} must be >= 0")
        if deadline_s is None:
            deadline_s = self.config.deadline_s
        if deadline_s is not None and deadline_s <= 0:
            raise ValueError(f"deadline_s={deadline_s} must be > 0")
        if len(prompt) + max_new > self.max_len:
            raise ValueError(
                f"request needs {len(prompt) + max_new} cache rows > "
                f"max_len={self.max_len}")
        if self.pages_needed(len(prompt), max_new) > self.pool.n_pages - 1:
            raise ValueError(
                f"request needs {self.pages_needed(len(prompt), max_new)} "
                f"pages > pool capacity {self.pool.n_pages - 1}")
        now = self._now()
        rid = self._next_rid
        self._next_rid += 1
        if len(self.sched.queue) >= self.max_queue:
            self.stats.rejected += 1
            self.sched.finished.append(RequestResult(
                rid, [int(t) for t in prompt], [], now, now, now,
                finish_reason=FinishReason.REJECTED,
                retry_after_s=self._retry_hint()))
            return rid
        self.sched.queue.append(Request(rid, [int(t) for t in prompt],
                                        int(max_new), float(temperature),
                                        seed, arrival_s=now,
                                        deadline_s=deadline_s))
        return rid

    def _retry_hint(self) -> float:
        """Backpressure hint for REJECTED results: the least-remaining
        in-flight slot's tokens at the observed decode rate (fallback 50
        ms/token before any decode has run)."""
        rem = [int(self.sched.remaining[i])
               for i, s in enumerate(self.sched.slots) if s is not None]
        per_tok = (self.stats.decode_s / self.stats.tokens_out
                   if self.stats.tokens_out and self.stats.decode_s
                   else 0.05)
        return round(max(min(rem) if rem else 1, 1) * max(per_tok, 1e-3), 3)

    def cancel(self, rid: int) -> bool:
        """Cancel a request by rid — queued or in flight.  Partial output is
        returned as a ``CANCELLED`` result from the next ``step()``; pages
        free immediately.  False when the rid is unknown or already done."""
        return self.sched.cancel(rid, self._now(), self.stats)

    # -- shutdown ---------------------------------------------------------

    def close(self) -> list[RequestResult]:
        """Retire everything in flight as ``CANCELLED``, free all pages, and
        verify the paging state reconciles to its initial state (free list
        full, radix refcounts zeroed).  Returns the drained results.
        Idempotent; ``submit``/``step`` refuse after close."""
        if self._closed:
            return []
        sched = self.sched
        now = self._now()
        for req in list(sched.queue):
            sched.queue.remove(req)
            self.stats.cancelled += 1
            sched.finished.append(
                sched.queue_result(req, now, FinishReason.CANCELLED))
        for i, slot in enumerate(sched.slots):
            if slot is not None:
                self.stats.cancelled += 1
                sched.retire(i, now, FinishReason.CANCELLED)
        if sched.radix is not None:
            sched.radix.clear()
        bad = check_invariants(self.pool, sched.radix, tables=sched.owned)
        if self.pool.num_free != self.pool.n_pages - 1:
            bad.append(f"pool leaked pages: {self.pool.num_free} free != "
                       f"{self.pool.n_pages - 1} usable")
        assert not bad, ("close(): paging state failed to reconcile: "
                         + "; ".join(bad))
        self._closed = True
        out, sched.finished = sched.finished, []
        return out

    def __enter__(self) -> "Engine":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # -- the tick ---------------------------------------------------------

    def _chunk_buf(self, n: int) -> int:
        """Static chunk-buffer size for an ``n``-token chunk: exactly
        ``chunk_tokens`` when chunking is on (one compiled variant total);
        otherwise the next power-of-two bucket (≤ log2(max_len) variants
        across all prompt lengths — this replaces the per-(prefix, suffix)
        executable cache)."""
        if self.chunk_tokens is not None:
            return self.chunk_tokens
        C = 8
        while C < n:
            C *= 2
        return min(C, round_up(self.max_len, 8))

    def _nan_targets(self) -> tuple[np.ndarray, bool]:
        """Consult the ``logits.nan`` fault point: when it fires, poison the
        lowest-index live decoding slot (or, with none, the in-flight prompt
        chunk) for this tick."""
        nanmask = np.zeros(self.max_batch, bool)
        chunk_nan = False
        if self.chaos is not None and self.chaos.fire("logits.nan"):
            live = [j for j, s in enumerate(self.sched.slots)
                    if s is not None and s.phase == DECODING
                    and self.sched.remaining[j] > 0]
            if live:
                nanmask[live[0]] = True
            else:
                chunk_nan = True
        return nanmask, chunk_nan

    def _mixed_tick(self, i: int, n: int):
        """Run the unified mixed step: ``n`` prompt rows of prefilling slot
        ``i`` plus one decode step for every decoding slot."""
        sched, runner = self.sched, self.runner
        if self.chaos is not None and self.chaos.fire("runner.mixed"):
            # pre-dispatch: no host or device state touched yet, so the
            # tick can simply be skipped and retried next step
            raise ChaosError("injected mixed-step failure")
        slot = sched.slots[i]
        full = slot.req.full_prompt()
        C = self._chunk_buf(n)
        buf = np.zeros((1, C), np.int32)
        buf[0, :n] = full[slot.offset: slot.offset + n]
        if sched.lazy:
            sched.grow_for_decode(1, self.stats)
        sched.check_capacity(1, self.stats)
        dec_pages = sched.pages.copy()
        dec_pages[i] = 0  # prefilling slot's frozen decode row -> trash page
        before = sched.remaining.copy()
        nanmask, chunk_nan = self._nan_targets()
        t0 = time.time()
        (runner.caches, tok0, key1, chunk_ok, cur, pos, remaining, keys,
         toks, oks) = \
            runner.mixed_fn(C, self.max_prefill_variants)(
                runner.params, runner.caches, jnp.asarray(buf),
                jnp.asarray(sched.pages[i: i + 1]), jnp.int32(slot.offset),
                jnp.int32(n), jnp.float32(slot.req.temperature),
                jnp.asarray(slot.key), jnp.asarray(chunk_nan),
                jnp.asarray(dec_pages),
                jnp.asarray(sched.cur), jnp.asarray(sched.pos),
                jnp.asarray(sched.remaining), jnp.asarray(sched.temp),
                jnp.asarray(sched.keys), jnp.asarray(nanmask))
        toks, oks = np.asarray(toks), np.asarray(oks)
        sched.cur, sched.pos = np.array(cur), np.array(pos)
        sched.remaining, sched.keys = np.array(remaining), np.array(keys)
        self.stats.prefill_s += time.time() - t0
        self.stats.mixed_steps += 1
        now = self._now()
        self._emit(toks, oks, before, now)
        if not bool(chunk_ok):
            # poisoned prompt chunk: isolate the prefilling request (its
            # pages were never published to the radix tree)
            self.stats.faults_isolated += 1
            sched.retire(i, now, FinishReason.FAULT)
            return
        slot.offset += n
        if slot.offset == len(full):
            sched.commit_prefill(i, int(tok0), key1, now, self.stats)

    def _decode_tick(self):
        """Run one fused decode chunk (no prefill work pending)."""
        sched, runner = self.sched, self.runner
        if self.chaos is not None and self.chaos.fire("runner.mixed"):
            raise ChaosError("injected decode-chunk failure")
        if sched.lazy:
            sched.grow_for_decode(self.decode_chunk, self.stats)
        sched.check_capacity(self.decode_chunk, self.stats)
        if not sched.num_active:
            return  # every slot was preempted while growing
        before = sched.remaining.copy()
        nanmask, _ = self._nan_targets()
        t0 = time.time()
        (runner.caches, cur, pos, remaining, keys, toks, oks) = \
            runner.decode_fn(
                runner.params, runner.caches, jnp.asarray(sched.pages),
                jnp.asarray(sched.cur), jnp.asarray(sched.pos),
                jnp.asarray(sched.remaining), jnp.asarray(sched.temp),
                jnp.asarray(sched.keys), jnp.asarray(nanmask))
        toks, oks = np.asarray(toks), np.asarray(oks)
        sched.cur, sched.pos = np.array(cur), np.array(pos)
        sched.remaining, sched.keys = np.array(remaining), np.array(keys)
        self.stats.decode_s += time.time() - t0
        self.stats.chunks += 1
        self._emit(toks, oks, before, self._now())

    def _emit(self, toks, oks, before, now: float):
        """Credit decoded tokens to their slots and retire finished ones.
        ``before`` (remaining at tick start) bounds each slot's share — a
        slot that was prefilling or frozen contributes nothing.  A step
        whose ``ok`` flag dropped marks a fault: tokens from that step on
        are discarded and the slot retires FAULT (isolated — the other
        slots' rows are untouched)."""
        for i, slot in enumerate(self.sched.slots):
            if slot is None or before[i] == 0:
                continue
            take = toks[i][: before[i]]
            bad = np.nonzero(~oks[i][: before[i]])[0]
            faulted = bad.size > 0
            if faulted:
                take = take[: bad[0]]
            if self.eos_id is not None:
                stop = np.nonzero(take == self.eos_id)[0]
                if stop.size:
                    take = take[: stop[0] + 1]
            slot.emitted.extend(int(t) for t in take)
            slot.token_times.extend(now for _ in take)
            self.stats.tokens_out += len(take)
            if faulted:
                self.stats.faults_isolated += 1
                self.sched.retire(i, now, FinishReason.FAULT)
            elif self.sched.remaining[i] == 0:
                self.sched.retire(i, now)

    def step(self) -> list[RequestResult]:
        """One scheduling iteration: expire deadlines, admit, then run
        either the unified mixed step (prompt chunk + one decode step each)
        or a fused decode-only chunk.  Returns newly finished requests
        (including rejected/cancelled/expired ones)."""
        if self._closed:
            raise RuntimeError("engine is closed; create a new Engine")
        sched = self.sched
        if self.chaos is not None:
            self.chaos.fire("clock.skew")  # may advance the injected clock
        sched.expire(self._now(), self.stats)
        sched.admit(self.runner, self.stats, self.max_prefill_variants)
        self.stats.peak_active = max(self.stats.peak_active, self.num_active)
        try:
            nc = sched.next_chunk()
            if nc is not None:
                self._mixed_tick(*nc)
            elif self.num_active:
                self._decode_tick()
        except ChaosError:
            pass  # injected transient tick failure: nothing dispatched; retry
        if self.radix is not None:
            self.stats.prefix_hit_tokens = self.radix.hit_tokens
            self.stats.prefix_lookup_tokens = self.radix.lookup_tokens
        out, sched.finished = sched.finished, []
        return out

    def run(self) -> list[RequestResult]:
        """Drive ``step`` until queue and slots drain; returns all results
        (rejected submissions included)."""
        results = []
        while self.sched.queue or self.num_active:
            results.extend(self.step())
        out, self.sched.finished = self.sched.finished, []
        results.extend(out)
        return results

    # ------------------------------------------------------------------
    # batch-generate compatibility surface (seed API)
    # ------------------------------------------------------------------

    def generate(self, prompts: list[list[int]], max_new: int = 32,
                 temperature: float = 0.0, seed: int = 0):
        """Submit a closed batch and run it to completion.  Returns
        ``(sequences, stats)`` like the seed engine: ``sequences[i]`` is
        prompt + generated for ``prompts[i]``."""
        t_stats = ServeStats(prefill_s=-self.stats.prefill_s,
                             decode_s=-self.stats.decode_s,
                             tokens_out=-self.stats.tokens_out,
                             prefills=-self.stats.prefills,
                             chunks=-self.stats.chunks,
                             mixed_steps=-self.stats.mixed_steps)
        rids = [self.submit(p, max_new, temperature, seed=seed * 1000003 + i)
                for i, p in enumerate(prompts)]
        by_rid = {r.rid: r for r in self.run()}
        out = [by_rid[r].tokens for r in rids]
        t_stats.prefill_s += self.stats.prefill_s
        t_stats.decode_s += self.stats.decode_s
        t_stats.tokens_out += self.stats.tokens_out
        t_stats.prefills += self.stats.prefills
        t_stats.chunks += self.stats.chunks
        t_stats.mixed_steps += self.stats.mixed_steps
        t_stats.peak_active = self.stats.peak_active
        t_stats.prefix_hit_tokens = self.stats.prefix_hit_tokens
        t_stats.prefix_lookup_tokens = self.stats.prefix_lookup_tokens
        return out, t_stats
