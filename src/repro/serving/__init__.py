from repro.core.cache import CacheLayout  # noqa: F401
from repro.serving.config import CacheSpec, EngineConfig  # noqa: F401
from repro.serving.engine import (Engine, Request, RequestResult,  # noqa: F401
                                  ServeStats, bytes_tokenizer_decode,
                                  bytes_tokenizer_encode)
from repro.serving.paging import PagePool, PrefixMatch, RadixCache  # noqa: F401
