from repro.core.cache import CacheLayout  # noqa: F401
from repro.serving.chaos import (FAULT_POINTS, ChaosError,  # noqa: F401
                                 ChaosInjector)
from repro.serving.config import CacheSpec, EngineConfig, MeshSpec  # noqa: F401
from repro.serving.engine import (Engine, FinishReason,  # noqa: F401
                                  ModelRunner, Request,
                                  RequestResult, Scheduler, ServeStats,
                                  bytes_tokenizer_decode,
                                  bytes_tokenizer_encode)
from repro.serving.paging import PagePool, PrefixMatch, RadixCache  # noqa: F401
