from repro.serving.engine import Engine, grow_cache  # noqa: F401
