from repro.core.cache import CacheLayout  # noqa: F401
from repro.serving.config import CacheSpec, EngineConfig, MeshSpec  # noqa: F401
from repro.serving.engine import (Engine, ModelRunner, Request,  # noqa: F401
                                  RequestResult, Scheduler, ServeStats,
                                  bytes_tokenizer_decode,
                                  bytes_tokenizer_encode)
from repro.serving.paging import PagePool, PrefixMatch, RadixCache  # noqa: F401
