from repro.serving.engine import (Engine, Request, RequestResult,  # noqa: F401
                                  ServeStats, bytes_tokenizer_decode,
                                  bytes_tokenizer_encode, grow_cache)
