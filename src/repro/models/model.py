"""Config-driven model assembly: param specs, forward, prefill, decode, loss.

One code path serves all 10 assigned architectures; the :class:`ArchConfig`
selects mixers (attention global/local, MLA, SSD, cross-attn) and FFNs
(dense / MoE) per layer via the stage machinery, and the whole stack runs as
``lax.scan`` over homogeneous layer groups (with configurable rematerialization)
so 95-layer models lower to compact HLO.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ArchConfig, LayerSpec, Stage
from repro.core.quant import QTensor
from repro.launch.sharding import constrain
from repro.models import layers as L
from repro.models import ssd as S
from repro.models.params import ParamSpec, init_params, shape_tree, stack_tree

F32 = jnp.float32


# ---------------------------------------------------------------------------
# Param specs
# ---------------------------------------------------------------------------

def _mixer_specs(cfg: ArchConfig, spec: LayerSpec) -> dict:
    if spec.mixer == "ssm":
        return S.ssd_specs(cfg)
    if cfg.use_mla:
        return L.mla_specs(cfg)
    if spec.mixer == "cross":
        return {"self": L.attn_specs(cfg), "cross": L.cross_attn_specs(cfg),
                "norm_cross": L.norm_specs(cfg)}
    return L.attn_specs(cfg)


def _layer_param_specs(cfg: ArchConfig, spec: LayerSpec) -> dict:
    d = {"norm1": L.norm_specs(cfg), "mixer": _mixer_specs(cfg, spec)}
    if spec.ffn == "dense":
        d["norm2"] = L.norm_specs(cfg)
        d["ffn"] = L.ffn_specs(cfg)
    elif spec.ffn == "moe":
        d["norm2"] = L.norm_specs(cfg)
        d["ffn"] = L.moe_specs(cfg)
    return d


def param_specs(cfg: ArchConfig, main_repeats: int | None = None) -> dict:
    D, Vp = cfg.d_model, cfg.padded_vocab
    tree: dict = {}
    if cfg.audio_frontend:
        tree["frontend_proj"] = ParamSpec((cfg.frontend_dim, D), ("frontend", "embed"))
    tree["embed"] = ParamSpec((Vp, D), ("vocab", "embed"), "normal")
    if cfg.vision_tokens:
        tree["vision_proj"] = ParamSpec((cfg.vision_dim, D), ("frontend", "embed"))
    stages = []
    for stage in cfg.stages(main_repeats):
        group = {str(i): _layer_param_specs(cfg, sp) for i, sp in enumerate(stage.group)}
        stages.append(stack_tree(group, stage.repeats))
    tree["stages"] = stages
    tree["final_norm"] = L.norm_specs(cfg)
    if not cfg.tie_embeddings:
        tree["lm_head"] = ParamSpec((D, Vp), ("embed", "vocab"), "normal")
    return tree


def init(cfg: ArchConfig, rng) -> dict:
    return init_params(param_specs(cfg), rng, cfg.param_dtype)


def param_shapes(cfg: ArchConfig, main_repeats: int | None = None):
    return shape_tree(param_specs(cfg, main_repeats), cfg.param_dtype)


# ---------------------------------------------------------------------------
# w8a8 weight quantization (one-time, at load)
# ---------------------------------------------------------------------------

# every weight consumed by ``layers.dense_proj``; anything else (norm scales,
# embeddings, RoPE-free SSM params, MoE expert tensors — batched einsum path,
# MLA's wq_b/wkv_b — needed in float for absorbed decode) stays float
_QUANT_NAMES = frozenset({"wq", "wk", "wv", "wo", "w_gate", "w_up", "w_down",
                          "w1", "w2", "wq_a", "wkv_a", "lm_head"})


def _quantize_weight(w, red_axes: tuple) -> QTensor:
    """Symmetric int8 over ``red_axes`` (the contraction dims): per-output-
    channel scales, broadcastable against the original weight shape."""
    wf = w.astype(F32)
    amax = jnp.max(jnp.abs(wf), axis=red_axes, keepdims=True)
    scale = jnp.maximum(amax, 1e-8) / 127.0
    q = jnp.clip(jnp.round(wf / scale), -127, 127).astype(jnp.int8)
    return QTensor(q, scale.astype(F32))


def quantize_params(cfg: ArchConfig, params: dict) -> dict:
    """Quantize every GEMM weight to int8 once at load (``quant="w8a8"``).

    Returns a params tree where each ``dense_proj`` weight is a ``QTensor``
    (int8 values + per-output-channel f32 scales, stacked-layer leading axis
    preserved so ``lax.scan`` slices it like any other param); activations
    are quantized per-row on the fly inside ``cgra_gemm_w8a8``.  Idempotent —
    already-quantized leaves pass through.  Inference-only: the int8 tree is
    not differentiable.
    """
    def walk(tree, stacked: bool):
        if not isinstance(tree, dict):
            return tree
        if "router" in tree:  # MoE expert weights stay on the einsum path
            return tree
        out = {}
        for name, v in tree.items():
            if isinstance(v, dict):
                out[name] = walk(v, stacked)
            elif (name in _QUANT_NAMES and not isinstance(v, QTensor)
                  and getattr(v, "ndim", 0) >= 2):
                s = 1 if stacked else 0  # skip the scanned layers axis
                red = tuple(range(s, v.ndim - 1)) if name == "wo" else (s,)
                out[name] = _quantize_weight(v, red)
            else:
                out[name] = v
        return out

    new = dict(params)
    new["stages"] = [walk(st, True) for st in params["stages"]]
    if "lm_head" in params and not isinstance(params["lm_head"], QTensor):
        new["lm_head"] = _quantize_weight(params["lm_head"], (0,))
    if cfg.tie_embeddings and "lm_head_q" not in params:
        # tied head: the embedding stays float (it is a gather table), but
        # the head GEMM gets its own int8 copy of embed.T (1/4 the bytes)
        new["lm_head_q"] = _quantize_weight(params["embed"].T, (0,))
    return new


def shard_params(cfg: ArchConfig, params: dict, mesh, *, fsdp: bool = False):
    """Place a params tree on ``mesh`` with the logical-axis TP rules
    (heads/ffn/vocab/experts → ``model``, divisibility fallback intact).

    Handles the two ways a serving params tree deviates from ``param_specs``:
    ``QTensor`` leaves (w8a8) are placed *replicated* — sharding the int8
    GEMM's contraction dim would re-quantize activations per shard and break
    single-device numerics parity (DESIGN.md §9) — and the tied-head extra
    ``lm_head_q`` key gets the float head's ("embed", "vocab") spec.
    """
    from jax.sharding import NamedSharding, PartitionSpec
    from repro.launch.sharding import resolve_pspec
    from repro.models.params import is_spec
    specs = param_specs(cfg)
    if "lm_head_q" in params:
        specs = dict(specs, lm_head_q=ParamSpec((cfg.d_model, cfg.padded_vocab),
                                                ("embed", "vocab")))
    repl = NamedSharding(mesh, PartitionSpec())

    def place(spec, val):
        if isinstance(val, QTensor):
            return QTensor(jax.device_put(val.q, repl),
                           jax.device_put(val.scale, repl))
        ns = NamedSharding(mesh, resolve_pspec(spec, mesh, fsdp=fsdp))
        return jax.device_put(val, ns)

    return jax.tree.map(place, specs, params, is_leaf=is_spec)


# ---------------------------------------------------------------------------
# Caches
# ---------------------------------------------------------------------------

def _layer_cache_specs(cfg: ArchConfig, spec: LayerSpec, batch: int, seq: int):
    if spec.mixer == "ssm":
        return S.ssd_cache_specs(cfg, batch)
    if cfg.use_mla:
        return L.mla_cache_specs(cfg, batch, seq)
    if spec.mixer == "cross":
        c = L.attn_cache_specs(cfg, batch, seq, local=False)
        K, dh, T = cfg.num_kv_heads, cfg.head_dim, cfg.vision_tokens
        c["ck"] = ParamSpec((batch, T, K, dh), ("batch", None, "kv_heads", "qk"), "zeros")
        c["cv"] = ParamSpec((batch, T, K, dh), ("batch", None, "kv_heads", "qk"), "zeros")
        return c
    return L.attn_cache_specs(cfg, batch, seq, local=(spec.mixer == "attn_local"))


def cache_specs(cfg: ArchConfig, batch: int, seq: int,
                main_repeats: int | None = None) -> list:
    """Decode-cache spec tree.  ``batch`` is the number of serving *slots*:
    the continuous-batching engine allocates this once at ``[slots, max_len]``
    and recycles rows, so ``seq`` is a fixed capacity, not a growing length."""
    out = []
    for stage in cfg.stages(main_repeats):
        group = {str(i): _layer_cache_specs(cfg, sp, batch, seq)
                 for i, sp in enumerate(stage.group)}
        out.append(stack_tree(group, stage.repeats))
    return out


def cache_shapes(cfg: ArchConfig, batch: int, seq: int,
                 main_repeats: int | None = None):
    return shape_tree(cache_specs(cfg, batch, seq, main_repeats), cfg.compute_dtype)


def init_cache(cfg: ArchConfig, batch: int, seq: int):
    tree = cache_specs(cfg, batch, seq)
    return jax.tree.map(
        lambda s: jnp.zeros(s.shape, s.dtype or cfg.compute_dtype),
        tree, is_leaf=lambda x: isinstance(x, ParamSpec))


def paged_cache_specs(cfg: ArchConfig, max_batch: int, n_pages: int,
                      page_size: int, main_repeats: int | None = None) -> list:
    """Paged decode-cache spec tree: every ``kv_seq`` leaf becomes a page
    *pool* ``[n_pages, page_size, ...]`` shared across sequences (per-sequence
    page tables map logical rows to pool pages; page 0 is the engine's
    reserved trash page).  Sliding-window layers get full-size pages like
    global ones — under paging they window via the decode validity bound,
    not a ring.  Leaves without a ``kv_seq`` axis (SSM state, cross-attn
    image KV) stay slot-indexed ``[max_batch, ...]``."""
    specs = cache_specs(cfg, max_batch, page_size, main_repeats)

    def to_pool(spec):
        if "kv_seq" not in spec.axes:
            return spec
        b = spec.axes.index("batch")
        s = spec.axes.index("kv_seq")
        shape = list(spec.shape)
        shape[b], shape[s] = n_pages, page_size  # window rings un-shrunk
        axes = list(spec.axes)
        axes[b] = None  # the pool's page axis is not a batch axis
        return ParamSpec(tuple(shape), tuple(axes), "zeros", spec.dtype)

    return jax.tree.map(to_pool, specs, is_leaf=lambda x: isinstance(x, ParamSpec))


def init_paged_cache(cfg: ArchConfig, max_batch: int, n_pages: int,
                     page_size: int):
    tree = paged_cache_specs(cfg, max_batch, n_pages, page_size)
    return jax.tree.map(
        lambda s: jnp.zeros(s.shape, s.dtype or cfg.compute_dtype),
        tree, is_leaf=lambda x: isinstance(x, ParamSpec))


def pad_cache_len(cfg: ArchConfig, caches, new_len: int,
                  main_repeats: int | None = None):
    """Zero-pad every ``kv_seq`` dim of a prefill cache tree up to
    ``new_len`` rows (decode capacity).  Replaces the deleted ``grow_cache``
    for the direct ``prefill(...)`` → ``decode_step`` loop; the serving
    engine allocates fixed-capacity paged pools instead."""
    specs = cache_specs(cfg, 1, new_len, main_repeats)

    def grow(spec, leaf):
        if "kv_seq" not in spec.axes:
            return leaf
        axis = spec.axes.index("kv_seq")
        pad = spec.shape[axis] - leaf.shape[axis]
        if pad <= 0:
            return leaf
        widths = [(0, 0)] * leaf.ndim
        widths[axis] = (0, pad)
        return jnp.pad(leaf, widths)

    return jax.tree.map(grow, specs, caches,
                        is_leaf=lambda x: isinstance(x, ParamSpec))


# ---------------------------------------------------------------------------
# Layer application
# ---------------------------------------------------------------------------

def _apply_layer(cfg: ArchConfig, spec: LayerSpec, p: dict, x, *, positions,
                 img, mode: str, cache=None, pos=None, pages=None,
                 full_kv: bool = False, attn_chunk: int = 0, chunk_len=None):
    """Returns (x, new_cache, aux).

    decode: ``cache`` is the layer's KV cache (slot-indexed, or a page pool
    when ``pages`` [B, npp] is given).  prefill: ``cache``, if set, is the
    layer's *past* KV ({"k","v"} [B, s, K, dh], post-RoPE — a radix-cache
    prefix hit) and ``positions`` must already be offset by ``s``;
    ``full_kv`` keeps sliding-window layers' full linear KV (paged serving)
    instead of the rolled ring.  chunk (chunked prefill): ``cache`` is the
    layer's page *pools*, ``pages`` the [B, npp] tables, ``chunk_len`` the
    valid rows in the chunk buffer — only prefix-decomposable mixers
    (pure attention) support it; SSM/MLA/cross raise."""
    aux = jnp.zeros((), F32)
    h = L.apply_norm(cfg, p["norm1"], x)
    new_cache = None
    local = spec.mixer == "attn_local"
    if spec.mixer == "ssm":
        if mode == "chunk":
            raise NotImplementedError("chunked prefill requires a prefix-"
                                      "decomposable mixer; SSM state is not")
        if mode == "decode":
            m, new_cache = S.ssd_decode(cfg, p["mixer"], cache, h)
        elif mode == "prefill":
            m, new_cache = S.ssd_forward(cfg, p["mixer"], h, return_cache=True)
        else:
            m = S.ssd_forward(cfg, p["mixer"], h)
    elif cfg.use_mla:
        if mode == "chunk":
            raise NotImplementedError("chunked prefill over the paged past "
                                      "does not support MLA's fused cache")
        if mode == "decode":
            m, new_cache = L.mla_decode(cfg, p["mixer"], cache, h, pos,
                                        pages=pages)
        elif mode == "prefill":
            m, new_cache = L.mla_prefill(cfg, p["mixer"], h, positions, attn_chunk)
        else:
            m = L.mla_forward(cfg, p["mixer"], h, positions, attn_chunk)
    elif spec.mixer == "cross":
        mp = p["mixer"]
        if mode == "chunk":
            raise NotImplementedError("chunked prefill does not support "
                                      "cross-attention image KV")
        if mode == "decode":
            m, sc = L.attn_decode(cfg, mp["self"], {"k": cache["k"], "v": cache["v"]},
                                  h, pos, local=False, pages=pages)
        elif mode == "prefill":
            m, sc = L.attn_prefill(cfg, mp["self"], h, positions, local=False,
                                   attn_chunk=attn_chunk)
        else:
            m = L.attn_forward(cfg, mp["self"], h, positions, local=False,
                               attn_chunk=attn_chunk)
            sc = None
        x = x + m
        hc = L.apply_norm(cfg, mp["norm_cross"], x)
        img_kv = (cache["ck"], cache["cv"]) if mode == "decode" else None
        mc, (ck, cv) = L.cross_attn(cfg, mp["cross"], hc, img, img_kv)
        if mode in ("decode", "prefill"):
            new_cache = dict(sc, ck=ck, cv=cv)
        m = mc  # residual added below
    else:
        if mode == "decode":
            m, new_cache = L.attn_decode(cfg, p["mixer"], cache, h, pos,
                                         local=local, pages=pages)
        elif mode == "chunk":
            m, new_cache = L.attn_chunk_prefill(cfg, p["mixer"], cache, h,
                                                positions, local=local,
                                                pages=pages,
                                                chunk_len=chunk_len)
        elif mode == "prefill":
            m, new_cache = L.attn_prefill(cfg, p["mixer"], h, positions, local=local,
                                          attn_chunk=attn_chunk, past_kv=cache,
                                          full_cache=full_kv)
        else:
            m = L.attn_forward(cfg, p["mixer"], h, positions, local=local,
                               attn_chunk=attn_chunk)
    x = x + m
    if spec.ffn != "none":
        h = L.apply_norm(cfg, p["norm2"], x)
        if spec.ffn == "moe":
            f, aux = L.moe_forward(cfg, p["ffn"], h)
        else:
            f = L.ffn_forward(cfg, p["ffn"], h)
        x = x + f
    return x, new_cache, aux


def _remat(cfg: ArchConfig, fn):
    if cfg.remat_policy == "none":
        return fn
    if cfg.remat_policy == "dots":
        pol = jax.checkpoint_policies.checkpoint_dots
        return jax.checkpoint(fn, policy=pol)
    if cfg.remat_policy == "dots_nb":
        # save weight-activation GEMM outputs, recompute batched einsums
        # (attention scores) — the memory/recompute sweet spot at depth
        pol = jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims
        return jax.checkpoint(fn, policy=pol)
    return jax.checkpoint(fn)  # "full": save nothing


def _apply_stage(cfg: ArchConfig, stage: Stage, sp, x, *, positions, img,
                 mode: str, caches=None, pos=None, pages=None,
                 full_kv: bool = False, attn_chunk: int = 0, chunk_len=None,
                 aux0=None):
    """Scan `stage.repeats` iterations of the layer group."""
    group = stage.group

    def body(carry, xs):
        xc, aux = carry
        xc = constrain(xc, ("batch", "seq", "embed"))  # pin the residual stream
        lp, lc = xs
        new_caches = {}
        for gi, spec in enumerate(group):
            c_in = None if lc is None else lc[str(gi)]
            xc, nc, a = _apply_layer(cfg, spec, lp[str(gi)], xc,
                                     positions=positions, img=img, mode=mode,
                                     cache=c_in, pos=pos, pages=pages,
                                     full_kv=full_kv, attn_chunk=attn_chunk,
                                     chunk_len=chunk_len)
            if nc is not None:
                new_caches[str(gi)] = nc
            aux = aux + a
        ys = new_caches if new_caches else None
        return (xc, aux), ys

    if mode == "train":
        body = _remat(cfg, body)
    xs = (sp, caches)
    if cfg.scan_layers:
        (x, aux), ys = lax.scan(body, (x, aux0), xs)
        return x, aux, ys
    # unrolled path: identical math, no `while` in HLO — used by the roofline
    # cost compiles, where XLA's cost analysis counts a scan body only once.
    aux = aux0
    ys_list = []
    for r in range(stage.repeats):
        xs_r = jax.tree.map(lambda a: a[r], xs)
        (x, aux), ys_r = body((x, aux), xs_r)
        ys_list.append(ys_r)
    if ys_list and ys_list[0] is not None:
        ys = jax.tree.map(lambda *a: jnp.stack(a), *ys_list)
    else:
        ys = None
    return x, aux, ys


# ---------------------------------------------------------------------------
# Embedding / head
# ---------------------------------------------------------------------------

def embed_tokens(cfg: ArchConfig, params, tokens):
    x = params["embed"][tokens].astype(cfg.compute_dtype)
    if cfg.name.startswith("gemma"):
        x = x * jnp.asarray(cfg.d_model ** 0.5, cfg.compute_dtype)
    return x


def embed_inputs(cfg: ArchConfig, params, batch: dict):
    if cfg.audio_frontend:
        return jnp.einsum("bsf,fd->bsd", batch["frames"].astype(cfg.compute_dtype),
                          params["frontend_proj"].astype(cfg.compute_dtype),
                          preferred_element_type=jnp.float32
                          ).astype(cfg.compute_dtype)
    return embed_tokens(cfg, params, batch["tokens"])


def project_images(cfg: ArchConfig, params, batch: dict):
    if not cfg.vision_tokens or "images" not in batch:
        return None
    return jnp.einsum("btf,fd->btd", batch["images"].astype(cfg.compute_dtype),
                      params["vision_proj"].astype(cfg.compute_dtype),
                      preferred_element_type=jnp.float32
                      ).astype(cfg.compute_dtype)


def lm_logits(cfg: ArchConfig, params, hidden):
    if cfg.tie_embeddings:
        head = params.get("lm_head_q", None)  # w8a8: int8 copy of embed.T
        head = params["embed"].T if head is None else head
    else:
        head = params["lm_head"]
    # f32 store: the GEMM epilogue's f32 accumulator reaches the sampler /
    # loss untouched instead of round-tripping through the compute dtype
    # (bf16 logits quantize argmax ties and top-k tails — analysis rule J006)
    logits = L.dense_proj(cfg, hidden, head, out_dtype=jnp.float32,
                          shard=("col", cfg.padded_vocab))
    return constrain(logits, ("batch", "seq", "vocab"))


# ---------------------------------------------------------------------------
# Public entry points
# ---------------------------------------------------------------------------

def forward_hidden(cfg: ArchConfig, params, batch: dict, *, mode="train",
                   caches=None, pos=None, pages=None, past_len=0,
                   full_kv: bool = False, attn_chunk: int = 0, chunk_len=None,
                   main_repeats: int | None = None):
    """Run the stack; returns (hidden, aux_loss, new_caches_per_stage).

    decode: ``caches`` is the per-stage cache tree; ``pages`` ([B, npp]
    int32) switches attention caches to paged pools indirected through the
    per-slot page table.  prefill: ``caches``, if given, is the *past* KV
    tree of a cached prefix of ``past_len`` tokens (suffix prefill — the
    prompt rows take positions ``past_len + arange(S)`` and attend over
    concat(past, new)); ``full_kv`` makes sliding-window layers return
    their full linear KV instead of a rolled ring (paged serving stores
    every row and windows at decode time).  chunk (chunked prefill):
    ``caches`` is the paged pool tree, ``pages`` the tables, ``past_len``
    (traced scalar ok) the rows already prefilled, ``chunk_len`` the valid
    rows in the fixed-size chunk buffer.
    """
    x = embed_inputs(cfg, params, batch)
    x = constrain(x, ("batch", "seq", "embed"))
    img = project_images(cfg, params, batch)
    seqlen = x.shape[1]
    if mode == "decode":
        positions = None
    else:
        positions = jnp.arange(seqlen, dtype=jnp.int32) + \
            jnp.asarray(past_len, jnp.int32)
    aux = jnp.zeros((), F32)
    new_caches = []
    for si, stage in enumerate(cfg.stages(main_repeats)):
        c = None if caches is None else caches[si]
        x, aux, ys = _apply_stage(cfg, stage, params["stages"][si], x,
                                  positions=positions, img=img, mode=mode,
                                  caches=c, pos=pos, pages=pages,
                                  full_kv=full_kv, attn_chunk=attn_chunk,
                                  chunk_len=chunk_len, aux0=aux)
        new_caches.append(ys)
    x = L.apply_norm(cfg, params["final_norm"], x)
    return x, aux, (new_caches if mode in ("prefill", "decode", "chunk")
                    else None)


def cross_entropy(cfg: ArchConfig, logits, labels):
    """Masked CE over the padded vocab.  logits: [B,S,Vp] (any float dtype)."""
    lf = logits.astype(F32)
    if cfg.padded_vocab != cfg.vocab_size:
        col = jnp.arange(cfg.padded_vocab)
        lf = jnp.where(col[None, None, :] < cfg.vocab_size, lf, L.NEG_INF)
    lse = jax.nn.logsumexp(lf, axis=-1)
    ll = jnp.take_along_axis(lf, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(lse - ll)


def loss_fn(cfg: ArchConfig, params, batch: dict, *, attn_chunk: int = 0,
            main_repeats: int | None = None):
    hidden, aux, _ = forward_hidden(cfg, params, batch, mode="train",
                                    attn_chunk=attn_chunk,
                                    main_repeats=main_repeats)
    logits = lm_logits(cfg, params, hidden)
    ce = cross_entropy(cfg, logits, batch["labels"])
    loss = ce + 0.01 * aux
    return loss, {"ce": ce, "aux": aux}


def prefill(cfg: ArchConfig, params, batch: dict, *, past=None,
            past_len: int = 0, full_kv: bool = False,
            cache_len: int | None = None, attn_chunk: int = 0,
            main_repeats: int | None = None):
    """Returns (last-token logits, caches).

    ``past``/``past_len``: cached-prefix KV tree + its token length (suffix
    prefill over a radix-cache hit; the returned caches hold only the new
    rows).  ``full_kv``: keep sliding-window layers' full linear KV (paged
    serving).  ``cache_len``: zero-pad every kv_seq dim to this capacity so
    the caches can be decoded into directly (replaces ``grow_cache``)."""
    hidden, _, caches = forward_hidden(cfg, params, batch, mode="prefill",
                                       caches=past, past_len=past_len,
                                       full_kv=full_kv, attn_chunk=attn_chunk,
                                       main_repeats=main_repeats)
    logits = lm_logits(cfg, params, hidden[:, -1:])
    if cache_len is not None:
        caches = pad_cache_len(cfg, caches, cache_len, main_repeats)
    return logits, caches


def decode_step(cfg: ArchConfig, params, caches, token, pos, *, pages=None,
                main_repeats: int | None = None):
    """One-token decode.  token: [B,1] int32; pos: scalar int32 (all slots in
    lock-step) or [B] int32 (slot-indexed — every sequence at its own offset,
    as driven by the continuous-batching engine).  ``pages`` ([B, npp] int32)
    switches attention caches to paged pools: the new row is written through
    the table and attention follows it (see ``layers.attn_decode``)."""
    batch = {"tokens": token}
    hidden, _, new_caches = forward_hidden(cfg, params, batch, mode="decode",
                                           caches=caches, pos=pos, pages=pages,
                                           main_repeats=main_repeats)
    logits = lm_logits(cfg, params, hidden)
    return logits, new_caches


def chunk_step(cfg: ArchConfig, params, caches, tokens, pages, past_len,
               chunk_len, *, main_repeats: int | None = None):
    """One chunked-prefill step: run a fixed-size prompt chunk through the
    paged cache.  tokens: [B, C] int32 chunk buffer (``chunk_len`` valid
    rows, rest padding); pages: [B, npp] page tables; ``past_len`` rows of
    this prompt are already in the pages (traced scalar ok).  The chunk's KV
    is written straight through the page table — no dense gather of the
    past — and the chunk attends over logical rows
    ``[0, past_len + chunk_len)``.  Returns (last-valid-row logits
    [B, 1, V], caches); the logits only mean anything when this chunk
    finishes the prompt."""
    batch = {"tokens": tokens}
    hidden, _, new_caches = forward_hidden(cfg, params, batch, mode="chunk",
                                           caches=caches, pages=pages,
                                           past_len=past_len,
                                           chunk_len=chunk_len,
                                           main_repeats=main_repeats)
    last = lax.dynamic_slice_in_dim(
        hidden, jnp.asarray(chunk_len, jnp.int32) - 1, 1, axis=1)
    logits = lm_logits(cfg, params, last)
    return logits, new_caches


def mixed_step(cfg: ArchConfig, params, caches, chunk_tokens, chunk_pages,
               chunk_past_len, chunk_len, dec_token, dec_pos, dec_pages, *,
               main_repeats: int | None = None):
    """The unified mixed step: one prompt chunk plus one decode token per
    slot, through shared layer application in a single compiled call.

    The chunk pass runs first (its KV lands in its own pages), then the
    decode pass runs over the updated pools — the two touch disjoint pages
    (a slot is either prefilling or decoding), so ordering is a dataflow
    convenience, not a semantic one.  Freeze a decode slot by pointing its
    ``dec_pages`` row at the trash page and ignoring its logits.  Returns
    (chunk_logits [Bc,1,V], dec_logits [B,1,V], caches)."""
    chunk_logits, caches = chunk_step(cfg, params, caches, chunk_tokens,
                                      chunk_pages, chunk_past_len, chunk_len,
                                      main_repeats=main_repeats)
    dec_logits, caches = decode_step(cfg, params, caches, dec_token, dec_pos,
                                     pages=dec_pages,
                                     main_repeats=main_repeats)
    return chunk_logits, dec_logits, caches
