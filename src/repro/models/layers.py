"""Reference (pure-jnp) transformer layers.

``cfg.kernel_mode`` selects the implementation of every GEMM-heavy op:

- ``reference`` — plain jnp einsum/matmul (dry-run oracle; the lowered HLO is
  analyzable by ``cost_analysis`` and the Pallas kernels validate against it)
- ``interpret`` — the Pallas CGRA block-GEMM / flash-attention kernels run
  through the interpreter (CPU validation of the exact kernel math)
- ``pallas`` — the compiled TPU kernels (the serving hot path)

All dense projections funnel through :func:`dense_proj` (which also serves
int8 ``QTensor`` weights, ``cfg.quant == "w8a8"``), forward/prefill
attention through :func:`dispatch_attend`, and single-token decode
attention through :func:`dispatch_attend_decode` (the flash-decode kernel
over the slot-indexed KV cache); see DESIGN.md §2/§6.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
from jax import lax

import functools

from repro.configs.base import ArchConfig
from repro.core import round_up
from repro.core.cache import CacheLayout
from repro.core.gemm import cgra_gemm, cgra_gemm_w8a8
from repro.core.quant import QTensor
from repro.kernels.ops import attend_decode as kernel_attend_decode
from repro.kernels.ops import attention as kernel_attention
from repro.launch.sharding import constrain, current_mesh, tp_shard_map
from repro.models.params import ParamSpec

F32 = jnp.float32
NEG_INF = -1e9

# When set (see launch/dryrun.py), `attend` replaces the score/softmax core
# with a stand-in that touches only q/k/v/o-sized tensors — i.e. exactly the
# HBM traffic of the Pallas flash kernel.  cost_analysis of this variant
# gives the flash-adjusted memory roofline term; never used for real math.
import contextvars

ATTN_STUB: contextvars.ContextVar = contextvars.ContextVar("attn_stub",
                                                           default=False)


# ---------------------------------------------------------------------------
# Dense projection — the single GEMM choke point of the model.
#
# Every weight-activation matmul (q/k/v/o projections, MLP, MLA low-rank
# projections, LM head) funnels through ``dense_proj`` so ``cfg.kernel_mode``
# selects the jnp reference path, the Pallas interpret path (CPU validation)
# or the compiled TPU block-GEMM — and pre-quantized ``QTensor`` weights
# (``cfg.quant == "w8a8"``, see ``models.model.quantize_params``) serve
# through the packed int8 kernel with its fused dequant epilogue.  MoE expert
# GEMMs stay on their einsum dispatch path (batched over experts).
# ---------------------------------------------------------------------------


def _tp_mesh(cfg):
    """(mesh, tp) when Pallas kernel calls must run per-shard under
    ``shard_map``: an activation mesh is active at trace time and
    ``cfg.kernel_mode`` routes through ``pallas_call`` (which has no SPMD
    partitioning rules — the reference jnp paths partition under XLA's auto
    partitioner and need none of this)."""
    mesh = current_mesh()
    if mesh is None or cfg.kernel_mode == "reference":
        return None, 1
    tp = dict(mesh.shape).get("model", 1)
    return (mesh, tp) if tp > 1 else (None, 1)


def _tp_gemm(mesh, tp, gemm, x, w, shard):
    """One GEMM under ``shard_map`` on the `model` axis.

    ``shard=("col", blocks)``: w [K, N] split on N into ``blocks`` logical
    column blocks (head / ffn / vocab units) — each device computes its
    output slice, no collective.  ``shard=("row", blocks)``: x/w split on the
    contraction dim K, partial GEMMs summed with an f32 psum (16-bit
    all-reduces trip an XLA CPU promotion-pass bug; see _moe_expert_block).
    Anything else (no hint, or ``blocks % tp != 0`` — matching the
    divisibility fallback that left the weight replicated): every device
    runs the whole GEMM replicated."""
    from jax.sharding import PartitionSpec as P
    nd = x.ndim
    kind, blocks = shard if shard else (None, 0)
    if kind == "col" and blocks % tp == 0:
        out_spec = P(*([None] * (nd - 1) + ["model"]))
        return tp_shard_map(gemm, mesh, (P(), P(None, "model")), out_spec)(x, w)
    if kind == "row" and blocks % tp == 0:
        def body(xs, ws):
            o = gemm(xs, ws)
            return lax.psum(o.astype(F32), "model").astype(o.dtype)
        x_spec = P(*([None] * (nd - 1) + ["model"]))
        return tp_shard_map(body, mesh, (x_spec, P("model", None)), P())(x, w)
    return tp_shard_map(gemm, mesh, (P(), P()), P())(x, w)


def dense_proj(cfg: ArchConfig, x, w, out_shape: tuple = (), out_dtype=None,
               shard: tuple | None = None):
    """x: [..., K] @ w -> [..., N] (or [..., *out_shape] with N = prod).

    ``w`` is either a float weight whose dims reshape row-major to [K, N]
    (e.g. wq: [D,H,dh] -> [D, H*dh]; wo: [H,dh,D] -> [H*dh, D] with the
    caller flattening x's head dims), or a ``QTensor`` holding the int8
    quantization of that same [K, N] reshape.  ``out_dtype`` overrides the
    store dtype of the accumulator (default: the compute dtype) — the
    logits head requests f32 so full precision survives to the sampler.

    ``shard=("col"|"row", blocks)`` is the tensor-parallel hint, used only
    when a mesh is active *and* the GEMM routes through Pallas (see
    ``_tp_gemm``); it must mirror how ``resolve_pspec`` placed the weight —
    "col" for output-dim sharding (wq/wk/wv/w_gate/w_up/lm_head), "row" for
    contraction-dim sharding (wo/w_down), ``blocks`` the logical unit count
    (heads / kv_heads / d_ff / padded_vocab) whose divisibility by tp gates
    the sharding.  QTensor weights are always placed replicated under a mesh
    (see ``model.shard_params``), so they take the replicated path.
    """
    Kdim = x.shape[-1]
    mesh, tp = _tp_mesh(cfg)
    if isinstance(w, QTensor):
        w2 = QTensor(w.q.reshape(Kdim, -1), w.scale.reshape(1, -1))
        gemm = functools.partial(cgra_gemm_w8a8, mode=cfg.kernel_mode,
                                 out_dtype=out_dtype or cfg.compute_dtype)
        shard = None  # int8 TP would re-quantize activations per shard
    else:
        w2 = w.reshape(Kdim, -1).astype(cfg.compute_dtype)
        gemm = functools.partial(cgra_gemm, mode=cfg.kernel_mode,
                                 out_dtype=out_dtype)
    if mesh is not None:
        out = _tp_gemm(mesh, tp, gemm, x, w2, shard)
    else:
        out = gemm(x, w2)
    if out_shape:
        out = out.reshape(*out.shape[:-1], *out_shape)
    return out


def dispatch_attend(cfg: ArchConfig, q, k, v, q_pos, k_pos, *, causal: bool,
                    window: int = 0, chunk: int = 0, softcap: float = 0.0):
    """kernel_mode-aware attention core.  Layout as ``attend``:
    q [B,Sq,H,d], k/v [B,Sk,K,d] -> [B,Sq,H,d].

    The flash kernel path covers the contiguous self/cross-attention pattern
    used by forward/prefill (positions are aranges with the last query
    aligned with the last key — ``Sq < Sk`` is suffix prefill over a cached
    prefix), preserving GQA grouping, sliding windows and logit softcap.
    The jnp ``attend`` stays the oracle for ``kernel_mode="reference"`` and
    for the roofline ATTN_STUB traffic stand-in; MLA keeps ``attend``
    unconditionally (its q/v head dims differ, which the prefill kernel
    accumulator does not model).

    Differentiability: the block GEMMs are trainable in every mode
    (``cgra_matmul`` carries a custom VJP) but the flash kernel has no VJP —
    train/finetune with ``kernel_mode="reference"``; interpret/pallas are
    the inference (serving) modes.
    """
    if cfg.kernel_mode == "reference" or ATTN_STUB.get():
        return attend(q, k, v, q_pos, k_pos, causal=causal, window=window,
                      chunk=chunk, softcap=softcap)
    call = functools.partial(kernel_attention, causal=causal, window=window,
                             softcap=softcap, mode=cfg.kernel_mode)
    qT, kT, vT = (t.transpose(0, 2, 1, 3) for t in (q, k, v))
    mesh, tp = _tp_mesh(cfg)
    if mesh is not None:
        from jax.sharding import PartitionSpec as P
        # per-device head shards keep the GQA fold intact (H/tp queries over
        # K/tp KV heads, same group size); non-divisible head counts fall
        # back to every device running the whole kernel replicated
        hs = "model" if (qT.shape[1] % tp == 0 and kT.shape[1] % tp == 0) \
            else None
        spec = P(None, hs, None, None)
        o = tp_shard_map(call, mesh, (spec, spec, spec), spec)(qT, kT, vT)
    else:
        o = call(qT, kT, vT)
    return o.transpose(0, 2, 1, 3)


def dispatch_attend_decode(cfg: ArchConfig, q, k, v, pos, start, *,
                           layout: str | CacheLayout = CacheLayout.LINEAR,
                           softcap: float = 0.0, scale=None,
                           dv: int | None = None, pages=None):
    """kernel_mode-aware single-token decode core.

    Cache-native layout in, model layout out: q [B,1,H,dq], cache k/v
    [B,S,K,d] -> [B,1,H,dv] — the kernel blocks the cache's S axis
    directly, so the hot path never transposes or copies it.
    ``pos``/``start`` are the per-slot [B] validity bounds (cache row of
    the current token / first live row — sliding-window layers on a linear
    or paged cache pass ``max(0, pos - window + 1)``); ``layout`` is the
    :class:`CacheLayout` validity rule; ``dv`` narrows the value read (MLA
    passes one concatenated cache as both k and v); ``pages`` ([B, npp])
    switches k/v to page pools indirected through the per-slot page table.
    Routes to the jnp oracle (``reference``) or the flash-decode Pallas
    kernel (``interpret`` | ``pallas``), which streams only live k-blocks.
    Under a mesh the kernel runs per-KV-head-shard inside ``shard_map``
    (page tables / validity bounds replicated, head fold untouched — each
    shard keeps its full query groups); MLA's fused single-KV-head pool and
    other non-divisible head counts run replicated.
    """
    q0 = q[:, 0]
    call = functools.partial(kernel_attend_decode, layout=layout,
                             softcap=softcap, scale=scale, dv=dv,
                             mode=cfg.kernel_mode)
    mesh, tp = _tp_mesh(cfg)
    if mesh is not None:
        from jax.sharding import PartitionSpec as P
        hs = "model" if (q0.shape[1] % tp == 0 and k.shape[-2] % tp == 0) \
            else None
        qspec = P(None, hs, None)            # q [B, H, dq]
        kvspec = P(None, None, hs, None)     # [B, S, K, d] or pool [P, ps, K, d]
        body = lambda qq, kk, vv, pp, ss, pg: call(qq, kk, vv, pp, ss, pages=pg)
        o = tp_shard_map(
            body, mesh,
            (qspec, kvspec, kvspec, P(None), P(None), P(None, None)),
            qspec)(q0, k, v, pos, start, pages)
    else:
        o = call(q0, k, v, pos, start, pages=pages)
    return o[:, None]


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def norm_specs(cfg: ArchConfig) -> dict:
    if cfg.norm_type == "rmsnorm":
        return {"scale": ParamSpec((cfg.d_model,), ("embed",), "ones")}
    if cfg.norm_type == "layernorm":
        return {"scale": ParamSpec((cfg.d_model,), ("embed",), "ones"),
                "bias": ParamSpec((cfg.d_model,), ("embed",), "zeros")}
    return {}  # layernorm_nonparam


def apply_norm(cfg: ArchConfig, p: dict, x):
    xf = x.astype(F32)
    if cfg.norm_type == "rmsnorm":
        xf = xf * lax.rsqrt(jnp.mean(xf * xf, -1, keepdims=True) + 1e-6)
        return (xf * p["scale"].astype(F32)).astype(x.dtype)
    mu = jnp.mean(xf, -1, keepdims=True)
    var = jnp.mean(jnp.square(xf - mu), -1, keepdims=True)
    xf = (xf - mu) * lax.rsqrt(var + 1e-6)
    if cfg.norm_type == "layernorm":
        xf = xf * p["scale"].astype(F32) + p["bias"].astype(F32)
    return xf.astype(x.dtype)


def rms_only(x, scale, eps=1e-6):
    xf = x.astype(F32)
    xf = xf * lax.rsqrt(jnp.mean(xf * xf, -1, keepdims=True) + eps)
    return (xf * scale.astype(F32)).astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope(x, positions, theta: float):
    """x: [B, S, n, d] (d even), positions: [S] (shared) or [B, S] (per-row,
    continuous-batching decode where every slot sits at its own offset)."""
    d = x.shape[-1]
    half = d // 2
    freq = theta ** (-jnp.arange(0, half, dtype=F32) / half)
    ang = positions.astype(F32)[..., :, None] * freq  # [S, half] | [B, S, half]
    cos = jnp.cos(ang)[..., :, None, :]  # broadcasts over B and heads
    sin = jnp.sin(ang)[..., :, None, :]
    x1, x2 = jnp.split(x.astype(F32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], -1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention core (reference).  Supports causal / bidirectional, sliding
# window, GQA grouping, optional query chunking (bounds the score-matrix
# footprint — "flash attention in jnp") and a numerically-identical
# unchunked path used for the roofline cost compiles.
# ---------------------------------------------------------------------------

def _valid_mask(q_pos, k_pos, causal: bool, window: int):
    """Boolean key-validity mask from absolute positions.

    ``q_pos``/``k_pos``: [S] (shared) or [B, S] (per-row — continuous
    batching, where every slot carries its own left-pad offset).  Returns
    [Sq, Sk] or [B, Sq, Sk].  Keys at negative positions are left-pad rows
    (positions are ``arange - start``) and are invalid for every query.
    """
    qp = q_pos[..., :, None]
    kp = k_pos[..., None, :]
    m = kp >= 0
    if causal:
        m = m & (kp <= qp)
    if window:
        m = m & (kp > qp - window)
    return m


def attend(q, k, v, q_pos, k_pos, *, causal: bool, window: int = 0,
           chunk: int = 0, softcap: float = 0.0):
    """q: [B,Sq,H,dq], k: [B,Sk,K,dq], v: [B,Sk,K,dv] -> [B,Sq,H,dv].

    GQA: H q-heads grouped onto K kv-heads (H % K == 0).  Positions may be
    shared ([S]) or per-row ([B, S]); queries whose every key is masked
    (e.g. left-pad rows) return zeros, matching the flash kernels.
    """
    B, Sq, H, dq = q.shape
    K = k.shape[2]
    G = H // K
    dv = v.shape[-1]
    scale = dq ** -0.5
    qg = q.reshape(B, Sq, K, G, dq)

    def _block(qb, q_pos_b):
        # qb: [B, sq, K, G, dq]; q_pos_b: [sq] or [B, sq]
        if ATTN_STUB.get():  # flash-traffic stand-in: q/k/v read, o write
            vm = jnp.mean(v, axis=1)  # [B,K,dv]
            km = jnp.sum(jnp.mean(k, axis=1), -1, keepdims=True)  # consume k
            qs = jnp.sum(qb, axis=-1, keepdims=True) * 1e-9  # consume q
            return (qs + (vm + km * 1e-9)[:, None, :, None, :]).astype(v.dtype)
        with jax.named_scope("attn_core"):
            s = jnp.einsum("bskgd,btkd->bkgst", qb, k,
                           preferred_element_type=F32) * scale
            if softcap:
                s = jnp.tanh(s / softcap) * softcap
            mask = _valid_mask(q_pos_b, k_pos, causal, window)
            if mask.ndim == 2:
                mask = mask[None]
            mb = mask[:, None, None]  # [B|1, 1, 1, sq, Sk] vs s [B,K,G,sq,Sk]
            s = jnp.where(mb, s, NEG_INF)
            s = jax.nn.softmax(s, axis=-1)
            s = jnp.where(mb, s, 0.0)  # all-masked rows -> zeros, not 1/Sk
            return jnp.einsum("bkgst,btkd->bskgd", s.astype(v.dtype), v,
                              preferred_element_type=F32).astype(v.dtype)

    if chunk and Sq > chunk:
        # pad the tail chunk so ragged Sq still runs blockwise (the padded
        # query rows are computed and sliced off, like the Pallas grid pad)
        pad = (-Sq) % chunk
        qgp = jnp.pad(qg, ((0, 0), (0, pad)) + ((0, 0),) * 3)
        nb = (Sq + pad) // chunk
        qb = qgp.reshape(B, nb, chunk, K, G, dq).transpose(1, 0, 2, 3, 4, 5)
        if q_pos.ndim == 2:  # per-row positions: [B, Sq] -> [nb, B, chunk]
            pp = jnp.pad(q_pos, ((0, 0), (0, pad)), mode="edge")
            pb = pp.reshape(B, nb, chunk).transpose(1, 0, 2)
        else:
            pp = jnp.pad(q_pos, (0, pad), mode="edge")
            pb = pp.reshape(nb, chunk)
        out = lax.map(lambda args: _block(*args), (qb, pb))
        out = out.transpose(1, 0, 2, 3, 4, 5).reshape(B, Sq + pad, K, G, dv)
        out = out[:, :Sq]
    else:
        out = _block(qg, q_pos)
    return out.reshape(B, Sq, H, dv)


# ---------------------------------------------------------------------------
# GQA attention layer (global or sliding-window local), with KV cache decode.
# ---------------------------------------------------------------------------

def attn_specs(cfg: ArchConfig, cross: bool = False) -> dict:
    H, K, dh = cfg.padded_heads, cfg.num_kv_heads, cfg.head_dim
    D = cfg.d_model
    p = {
        "wq": ParamSpec((D, H, dh), ("embed", "heads", "qk")),
        "wk": ParamSpec((D, K, dh), ("embed", "kv_heads", "qk")),
        "wv": ParamSpec((D, K, dh), ("embed", "kv_heads", "qk")),
        "wo": ParamSpec((H, dh, D), ("heads", "qk", "embed")),
    }
    if getattr(cfg, "use_qk_norm", False):
        p["q_norm"] = ParamSpec((dh,), (None,), "ones")
        p["k_norm"] = ParamSpec((dh,), (None,), "ones")
    return p


def _qkv(cfg, p, xq, xkv):
    H, K, dh = cfg.padded_heads, cfg.num_kv_heads, cfg.head_dim
    q = dense_proj(cfg, xq, p["wq"], (H, dh), shard=("col", H))
    k = dense_proj(cfg, xkv, p["wk"], (K, dh), shard=("col", K))
    v = dense_proj(cfg, xkv, p["wv"], (K, dh), shard=("col", K))
    if "q_norm" in p:
        q = rms_only(q, p["q_norm"])
        k = rms_only(k, p["k_norm"])
    # pin batch/head sharding at the attention boundary — without this the
    # partitioner replicated pure-FSDP score tensors over the model axis
    # (measured: 64 GiB f32 scores on deepseek; EXPERIMENTS.md §Perf)
    q = constrain(q, ("batch", None, "heads", None))
    k = constrain(k, ("batch", None, "kv_heads", None))
    v = constrain(v, ("batch", None, "kv_heads", None))
    return q, k, v


def attn_forward(cfg: ArchConfig, p: dict, x, positions, *, local: bool,
                 attn_chunk: int = 0):
    """Training / encoder self-attention.  x: [B,S,D]."""
    q, k, v = _qkv(cfg, p, x, x)
    theta = cfg.rope_theta if not local else 10_000.0
    q = rope(q, positions, theta)
    k = rope(k, positions, theta)
    causal = cfg.kind == "decoder"
    window = cfg.window_size if local else 0
    o = dispatch_attend(cfg, q, k, v, positions, positions, causal=causal,
                        window=window, chunk=attn_chunk,
                        softcap=cfg.logit_softcap)
    o = constrain(o, ("batch", None, "heads", None))
    return dense_proj(cfg, o.reshape(*o.shape[:-2], -1), p["wo"],
                      shard=("row", cfg.padded_heads))


def attn_cache_specs(cfg: ArchConfig, batch: int, seq: int, local: bool) -> dict:
    K, dh = cfg.num_kv_heads, cfg.head_dim
    S = min(seq, cfg.window_size) if (local and cfg.window_size) else seq
    return {
        "k": ParamSpec((batch, S, K, dh), ("batch", "kv_seq", "kv_heads", "qk"), "zeros"),
        "v": ParamSpec((batch, S, K, dh), ("batch", "kv_seq", "kv_heads", "qk"), "zeros"),
    }


def _page_row_write(pool, new_row, pages, pos):
    """Scatter one row per sequence into a page pool.

    pool: [P, ps, ...]; new_row: [B, ...]; pages: [B, npp]; pos: [B].
    Logical row ``pos`` of sequence ``b`` lands at pool row
    ``(pages[b, pos // ps], pos % ps)``.  Rows whose page index would fall
    off the table are dropped, never clamped (the engine errors on
    capacity overrun before this can matter)."""
    P, ps = pool.shape[0], pool.shape[1]
    B = new_row.shape[0]
    npp = pages.shape[1]
    ipage = pos // ps
    flat = jnp.where(ipage < npp,
                     pages[jnp.arange(B), jnp.minimum(ipage, npp - 1)] * ps
                     + pos % ps,
                     P * ps)  # out of range -> dropped by mode="drop"
    pooled = pool.reshape(P * ps, *pool.shape[2:])
    pooled = pooled.at[flat].set(new_row.astype(pool.dtype), mode="drop")
    return pooled.reshape(pool.shape)


def attn_prefill(cfg: ArchConfig, p: dict, x, positions, *, local: bool,
                 attn_chunk: int = 0, past_kv=None, full_cache: bool = False):
    """Returns (out, cache).  Cache keys are post-RoPE (standard practice).

    ``positions``: [S] absolute positions of the prompt rows (for suffix
    prefill over a cached prefix of length ``s``, ``s + arange(S)``).
    ``past_kv`` ({"k","v"}: [B, s, K, dh], post-RoPE) is that prefix's KV,
    gathered from the paged cache — attention runs over the dense
    concat(past, new) with the last query aligned with the last key, and
    the returned cache holds only the NEW rows (the caller owns the prefix
    pages already).  ``full_cache`` keeps sliding-window layers' full
    linear k/v instead of the rolled ring (the paged engine stores every
    row and windows via decode validity).
    """
    q, k, v = _qkv(cfg, p, x, x)
    theta = cfg.rope_theta if not local else 10_000.0
    q = rope(q, positions, theta)
    k = rope(k, positions, theta)
    window = cfg.window_size if local else 0
    k_all, v_all = k, v
    if past_kv is not None:
        k_all = jnp.concatenate([past_kv["k"].astype(k.dtype), k], axis=1)
        v_all = jnp.concatenate([past_kv["v"].astype(v.dtype), v], axis=1)
    k_pos = jnp.arange(k_all.shape[1], dtype=jnp.int32)
    o = dispatch_attend(cfg, q, k_all, v_all, positions, k_pos, causal=True,
                        window=window, chunk=attn_chunk,
                        softcap=cfg.logit_softcap)
    out = dense_proj(cfg, o.reshape(*o.shape[:-2], -1), p["wo"],
                     shard=("row", cfg.padded_heads))
    if window and not full_cache and past_kv is None and k.shape[1] > window:
        # ring-buffer cache: keep the last `window` keys, rolled so entry
        # (pos % window) holds absolute position pos — decode continues the
        # ring seamlessly
        S = k.shape[1]
        k = jnp.roll(k[:, -window:], (S - window) % window, axis=1)
        v = jnp.roll(v[:, -window:], (S - window) % window, axis=1)
    return out, {"k": k, "v": v}


def _page_rows_write(pool, new_rows, pages, pos0, n):
    """Scatter a *chunk* of rows per sequence into a page pool.

    pool: [P, ps, ...]; new_rows: [B, C, ...]; pages: [B, npp]; pos0/n: [B].
    Chunk row ``i`` of sequence ``b`` is logical row ``pos0[b] + i`` and
    lands at pool row ``(pages[b, r // ps], r % ps)``.  Rows at ``i >= n[b]``
    (the chunk's padding) and rows whose page index would fall off the table
    are dropped, never clamped — same contract as :func:`_page_row_write`."""
    P, ps = pool.shape[0], pool.shape[1]
    B, C = new_rows.shape[0], new_rows.shape[1]
    npp = pages.shape[1]
    r = pos0[:, None] + jnp.arange(C, dtype=jnp.int32)[None]  # [B, C]
    ipage = r // ps
    ok = (jnp.arange(C)[None] < n[:, None]) & (ipage < npp)
    flat = jnp.where(
        ok,
        jnp.take_along_axis(pages, jnp.minimum(ipage, npp - 1), axis=1) * ps
        + r % ps,
        P * ps)  # out of range -> dropped by mode="drop"
    pooled = pool.reshape(P * ps, *pool.shape[2:])
    pooled = pooled.at[flat.reshape(-1)].set(
        new_rows.reshape(B * C, *new_rows.shape[2:]).astype(pool.dtype),
        mode="drop")
    return pooled.reshape(pool.shape)


def attn_chunk_prefill(cfg: ArchConfig, p: dict, cache: dict, x, positions, *,
                       local: bool, pages, chunk_len):
    """Chunked prefill over a paged past: one fixed-size prompt chunk.

    x: [B, C, D] — a size-C chunk buffer holding ``chunk_len`` valid prompt
    rows (the rest is padding); ``positions``: [C] or [B, C] absolute
    positions (``past_len + arange(C)``); cache: page pools [P, ps, K, dh];
    pages: [B, npp] page tables; ``chunk_len``: scalar or [B] int32.

    Writes the chunk's post-RoPE KV straight through the page table (no
    dense gather of the past — the cached prefix stays in its pages) and
    attends the query chunk over logical rows ``[0, past_len + chunk_len)``
    via the paged flash-attention layout.  Padding rows beyond ``chunk_len``
    produce garbage outputs the caller must ignore (the engine only reads
    the last valid row); their KV writes are dropped.  Returns
    (out, updated page pools)."""
    B, C = x.shape[0], x.shape[1]
    q, k_new, v_new = _qkv(cfg, p, x, x)
    theta = cfg.rope_theta if not local else 10_000.0
    positions = jnp.asarray(positions, jnp.int32)
    if positions.ndim == 1:
        positions = jnp.broadcast_to(positions[None], (B, C))
    q = rope(q, positions, theta)
    k_new = rope(k_new, positions, theta)
    pages = jnp.asarray(pages, jnp.int32)
    chunk_len = jnp.broadcast_to(jnp.asarray(chunk_len, jnp.int32), (B,))
    pos0 = positions[:, 0]
    k = _page_rows_write(cache["k"], k_new, pages, pos0, chunk_len)
    v = _page_rows_write(cache["v"], v_new, pages, pos0, chunk_len)
    window = cfg.window_size if local else 0
    call = functools.partial(kernel_attention, window=window,
                             softcap=cfg.logit_softcap, mode=cfg.kernel_mode)
    qT = q.transpose(0, 2, 1, 3)
    mesh, tp = _tp_mesh(cfg)
    if mesh is not None:
        from jax.sharding import PartitionSpec as P
        hs = "model" if (qT.shape[1] % tp == 0 and k.shape[2] % tp == 0) \
            else None
        body = lambda qq, kk, vv, pg, qs, kl: call(qq, kk, vv, pages=pg,
                                                   q_start=qs, k_len=kl)
        o = tp_shard_map(
            body, mesh,
            (P(None, hs, None, None), P(None, None, hs, None),
             P(None, None, hs, None), P(None, None), P(None), P(None)),
            P(None, hs, None, None))(qT, k, v, pages, pos0,
                                     pos0 + chunk_len)
    else:
        o = call(qT, k, v, pages=pages, q_start=pos0, k_len=pos0 + chunk_len)
    o = o.transpose(0, 2, 1, 3)
    o = constrain(o, ("batch", None, "heads", None))
    out = dense_proj(cfg, o.reshape(*o.shape[:-2], -1), p["wo"],
                     shard=("row", cfg.padded_heads))
    return out, {"k": k, "v": v}


def attn_decode(cfg: ArchConfig, p: dict, cache: dict, x, pos, *, local: bool,
                pages=None):
    """One-token decode.  x: [B,1,D]; pos: scalar int32 or [B] int32 (cache
    row of the current token, per batch slot — continuous batching runs
    every slot at its own offset).

    Unpaged: local layers use a ring-buffer cache of size `window` (write
    at ``pos % window``); global layers write at ``pos``.  A global-layer
    write at ``pos >= S`` is *dropped* (``mode="drop"``) rather than
    clamped onto the last slot — overrunning the cache must never corrupt
    slot ``S-1``; the serving engine refuses to decode past capacity
    (explicit length error) before this can happen.

    Paged (``pages`` given): the cache is a page pool [P, ps, K, dh] shared
    across the batch; the write lands at the page-table row for ``pos`` and
    attention follows the table (CacheLayout.PAGED).  Sliding-window layers
    store full rows like global ones and window via the validity lower
    bound ``start = max(0, pos - window + 1)`` — no ring under paging.

    The attention core routes through :func:`dispatch_attend_decode`;
    RoPE is pre-applied to cached keys, so scores need no position
    reconstruction.
    """
    B = x.shape[0]
    pos = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (B,))  # slot-indexed
    q, k_new, v_new = _qkv(cfg, p, x, x)
    theta = cfg.rope_theta if not local else 10_000.0
    rp = pos[:, None]
    q = rope(q, rp, theta)
    k_new = rope(k_new, rp, theta)
    window = cfg.window_size if local else 0
    if pages is not None:
        pages = jnp.asarray(pages, jnp.int32)
        k = _page_row_write(cache["k"], k_new[:, 0], pages, pos)
        v = _page_row_write(cache["v"], v_new[:, 0], pages, pos)
        start = jnp.maximum(pos - window + 1, 0) if window else None
        o = dispatch_attend_decode(cfg, q, k, v, pos, start,
                                   layout=CacheLayout.PAGED, pages=pages,
                                   softcap=cfg.logit_softcap)
    else:
        S = cache["k"].shape[1]
        ring = bool(local and cfg.window_size)
        widx = (pos % S) if ring else pos
        bidx = jnp.arange(B)
        k = cache["k"].at[bidx, widx].set(k_new[:, 0].astype(cache["k"].dtype),
                                          mode="drop")
        v = cache["v"].at[bidx, widx].set(v_new[:, 0].astype(cache["v"].dtype),
                                          mode="drop")
        o = dispatch_attend_decode(
            cfg, q, k, v, pos, None,
            layout=CacheLayout.RING if ring else CacheLayout.LINEAR,
            softcap=cfg.logit_softcap)
    H = q.shape[2]
    o = o.reshape(B, 1, H * v.shape[-1])
    out = dense_proj(cfg, o, p["wo"], shard=("row", cfg.padded_heads))
    return out, {"k": k, "v": v}


# ---------------------------------------------------------------------------
# MLA — multi-head latent attention (DeepSeek-V2 / MiniCPM3 style)
# ---------------------------------------------------------------------------

def mla_specs(cfg: ArchConfig) -> dict:
    D, H = cfg.d_model, cfg.padded_heads
    qr, kvr = cfg.q_lora_rank, cfg.kv_lora_rank
    dn, dr, dv = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    return {
        "wq_a": ParamSpec((D, qr), ("embed", "lora")),
        "q_norm": ParamSpec((qr,), (None,), "ones"),
        "wq_b": ParamSpec((qr, H, dn + dr), ("lora", "heads", "qk")),
        "wkv_a": ParamSpec((D, kvr + dr), ("embed", "lora")),
        "kv_norm": ParamSpec((kvr,), (None,), "ones"),
        "wkv_b": ParamSpec((kvr, H, dn + dv), ("lora", "heads", "qk")),
        "wo": ParamSpec((H, dv, D), ("heads", "qk", "embed")),
    }


def _mla_q(cfg, p, x, positions):
    dn, dr = cfg.qk_nope_dim, cfg.qk_rope_dim
    cq = rms_only(dense_proj(cfg, x, p["wq_a"]), p["q_norm"])
    q = dense_proj(cfg, cq, p["wq_b"], (cfg.padded_heads, dn + dr),
                   shard=("col", cfg.padded_heads))
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    q_rope = rope(q_rope, positions, cfg.rope_theta)
    return q_nope, q_rope


def _mla_latent(cfg, p, x, positions):
    kvr, dr = cfg.kv_lora_rank, cfg.qk_rope_dim
    ckv = dense_proj(cfg, x, p["wkv_a"])
    latent, k_rope = ckv[..., :kvr], ckv[..., kvr:]
    latent = rms_only(latent, p["kv_norm"])
    k_rope = rope(k_rope[:, :, None, :], positions, cfg.rope_theta)[:, :, 0]
    return latent, k_rope  # [B,S,kvr], [B,S,dr]


def mla_forward(cfg: ArchConfig, p: dict, x, positions, attn_chunk: int = 0):
    dn, dv = cfg.qk_nope_dim, cfg.v_head_dim
    q_nope, q_rope = _mla_q(cfg, p, x, positions)
    latent, k_rope = _mla_latent(cfg, p, x, positions)
    kv = dense_proj(cfg, latent, p["wkv_b"], (cfg.padded_heads, dn + dv),
                    shard=("col", cfg.padded_heads))
    k_nope, v = kv[..., :dn], kv[..., dn:]
    H = k_nope.shape[2]
    k_rope_b = jnp.broadcast_to(k_rope[:, :, None, :], k_rope.shape[:2] + (H, k_rope.shape[-1]))
    q = jnp.concatenate([q_nope, q_rope], -1)
    k = jnp.concatenate([k_nope, k_rope_b], -1)
    # MLA stays on the jnp attend core: q/k head dim (dn+dr) != v head dim
    o = attend(q, k, v, positions, positions, causal=(cfg.kind == "decoder"),
               chunk=attn_chunk)
    return dense_proj(cfg, o.reshape(*o.shape[:-2], -1), p["wo"],
                      shard=("row", cfg.padded_heads))


def mla_cache_specs(cfg: ArchConfig, batch: int, seq: int) -> dict:
    # one fused [latent | k_rope] cache per layer: decode reads it as both
    # keys (full width) and values (first kv_lora_rank columns), so the hot
    # path never concatenates or slices the cache
    return {
        "kv": ParamSpec((batch, seq, cfg.kv_lora_rank + cfg.qk_rope_dim),
                        ("batch", "kv_seq", None), "zeros"),
    }


def mla_prefill(cfg: ArchConfig, p: dict, x, positions, attn_chunk: int = 0):
    out = mla_forward(cfg, p, x, positions, attn_chunk)
    latent, k_rope = _mla_latent(cfg, p, x, positions)
    return out, {"kv": jnp.concatenate([latent,
                                        k_rope.astype(latent.dtype)], -1)}


def mla_decode(cfg: ArchConfig, p: dict, cache: dict, x, pos, *, pages=None):
    """Weight-absorbed MLA decode: attention runs in the latent space, so the
    per-step cost is O(S * kv_lora_rank) instead of O(S * H * head_dim) —
    the cached latent is never re-expanded.  (This is the paper's data-reuse
    insight applied to the KV cache.)

    The latent-space core is the flash-decode kernel in MQA form: queries
    ``[q_absorbed | q_rope]`` against the fused ``[latent | k_rope]`` cache,
    which is passed as *both* keys (full width, qk dim ``kvr +
    qk_rope_dim``) and values (first ``kvr`` columns, selected by the
    BlockSpec — no slicing copy).  With ``pages`` the cache is a
    [P, ps, kvr+dr] page pool written through the per-slot table, exactly
    as in :func:`attn_decode`.
    """
    dn, dv = cfg.qk_nope_dim, cfg.v_head_dim
    kvr = cfg.kv_lora_rank
    B = x.shape[0]
    pos = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (B,))  # slot-indexed
    rp = pos[:, None]
    q_nope, q_rope = _mla_q(cfg, p, x, rp)  # [B,1,H,dn],[B,1,H,dr]
    latent_new, k_rope_new = _mla_latent(cfg, p, x, rp)
    # out-of-capacity writes are dropped, never clamped onto the last row
    # (same invariant as attn_decode; the engine errors before this happens)
    row = jnp.concatenate([latent_new, k_rope_new.astype(latent_new.dtype)],
                          -1)[:, 0]
    if pages is not None:
        pages = jnp.asarray(pages, jnp.int32)
        kv = _page_row_write(cache["kv"], row, pages, pos)
        kv4 = kv[:, :, None]  # [P,ps,1,kvr+dr] pool; same array as k AND v
    else:
        bidx = jnp.arange(B)
        kv = cache["kv"].at[bidx, pos].set(row.astype(cache["kv"].dtype),
                                           mode="drop")
        kv4 = kv[:, :, None]  # [B,S,1,kvr+dr]; same array as k AND v
    wkv_b = p["wkv_b"].astype(cfg.compute_dtype)  # [kvr, H, dn+dv]
    wk, wv = wkv_b[..., :dn], wkv_b[..., dn:]
    # absorb: q_lat[b,h,r] = sum_d q_nope[b,h,d] wk[r,h,d]
    q_lat = jnp.einsum("bshd,rhd->bshr", q_nope, wk,
                       preferred_element_type=F32).astype(q_nope.dtype)
    q_cat = jnp.concatenate([q_lat, q_rope.astype(q_lat.dtype)], -1)
    o_lat = dispatch_attend_decode(
        cfg, q_cat, kv4, kv4, pos, None,
        layout=CacheLayout.PAGED if pages is not None else CacheLayout.LINEAR,
        pages=pages, scale=(dn + cfg.qk_rope_dim) ** -0.5, dv=kvr)
    o = jnp.einsum("bshr,rhd->bshd", o_lat, wv,  # expand to v space
                   preferred_element_type=F32).astype(o_lat.dtype)
    out = dense_proj(cfg, o.reshape(*o.shape[:-2], -1), p["wo"],
                     shard=("row", cfg.padded_heads))
    return out, {"kv": kv}


# ---------------------------------------------------------------------------
# Cross-attention sub-block (Llama-3.2-Vision style)
# ---------------------------------------------------------------------------

def cross_attn_specs(cfg: ArchConfig) -> dict:
    p = attn_specs(cfg)
    p["gate"] = ParamSpec((), (), "zeros")  # tanh-gated residual
    return p


def cross_attn(cfg: ArchConfig, p: dict, x, img, img_kv=None):
    """x: [B,S,D] text hidden; img: [B,T,D] projected image embeddings.
    Returns (out, (k, v)) so decode can reuse the static cross KV."""
    H, K, dh = cfg.padded_heads, cfg.num_kv_heads, cfg.head_dim
    if img_kv is None:
        k = dense_proj(cfg, img, p["wk"], (K, dh), shard=("col", K))
        v = dense_proj(cfg, img, p["wv"], (K, dh), shard=("col", K))
        if "q_norm" in p:
            k = rms_only(k, p["k_norm"])
    else:
        k, v = img_kv
    q = dense_proj(cfg, x, p["wq"], (H, dh), shard=("col", H))
    if "q_norm" in p:
        q = rms_only(q, p["q_norm"])
    Sq, T = q.shape[1], k.shape[1]
    o = dispatch_attend(cfg, q, k, v, jnp.arange(Sq), jnp.arange(T),
                        causal=False)
    o = dense_proj(cfg, o.reshape(*o.shape[:-2], -1), p["wo"],
                   shard=("row", H))
    return jnp.tanh(p["gate"].astype(F32)).astype(o.dtype) * o, (k, v)


# ---------------------------------------------------------------------------
# FFNs
# ---------------------------------------------------------------------------

def ffn_kind(cfg: ArchConfig) -> str:
    if cfg.name.startswith("gemma"):
        return "geglu"
    if cfg.family == "audio":
        return "gelu_mlp"
    return "swiglu"


def ffn_specs(cfg: ArchConfig) -> dict:
    D, Fdim = cfg.d_model, cfg.d_ff
    if ffn_kind(cfg) == "gelu_mlp":
        return {"w1": ParamSpec((D, Fdim), ("embed", "ffn")),
                "b1": ParamSpec((Fdim,), ("ffn",), "zeros"),
                "w2": ParamSpec((Fdim, D), ("ffn", "embed")),
                "b2": ParamSpec((D,), ("embed",), "zeros")}
    return {"w_gate": ParamSpec((D, Fdim), ("embed", "ffn")),
            "w_up": ParamSpec((D, Fdim), ("embed", "ffn")),
            "w_down": ParamSpec((Fdim, D), ("ffn", "embed"))}


def ffn_forward(cfg: ArchConfig, p: dict, x):
    dt = cfg.compute_dtype
    kind = ffn_kind(cfg)
    Fdim = cfg.d_ff
    if kind == "gelu_mlp":
        h = dense_proj(cfg, x, p["w1"], shard=("col", Fdim)) + p["b1"].astype(dt)
        h = jax.nn.gelu(h)
        return dense_proj(cfg, h, p["w2"], shard=("row", Fdim)) + p["b2"].astype(dt)
    g = dense_proj(cfg, x, p["w_gate"], shard=("col", Fdim))
    u = dense_proj(cfg, x, p["w_up"], shard=("col", Fdim))
    act = jax.nn.gelu(g, approximate=True) if kind == "geglu" else jax.nn.silu(g)
    return dense_proj(cfg, act * u, p["w_down"], shard=("row", Fdim))


# ---------------------------------------------------------------------------
# MoE FFN — capacity-factor top-k dispatch (Switch-style), SPMD-friendly:
# tokens grouped along the data axis, experts sharded along the model axis;
# the group->expert reshard is the MoE all-to-all.
# ---------------------------------------------------------------------------

def moe_specs(cfg: ArchConfig) -> dict:
    D, Fdim, E = cfg.d_model, cfg.moe_d_ff, cfg.num_experts
    return {
        "router": ParamSpec((D, E), ("embed", "experts"), "normal", jnp.float32),
        "w_gate": ParamSpec((E, D, Fdim), ("experts", "embed", "ffn")),
        "w_up": ParamSpec((E, D, Fdim), ("experts", "embed", "ffn")),
        "w_down": ParamSpec((E, Fdim, D), ("experts", "ffn", "embed")),
    }


def moe_capacity(cfg: ArchConfig, tokens_per_group: int) -> int:
    c = int(tokens_per_group * cfg.experts_per_token * cfg.capacity_factor
            / cfg.num_experts)
    return max(4, round_up(max(c, 1), 4))


def _moe_expert_block(xt, wk3, idx3, sel3, pos3, wg, wu, wd, *, E_l: int,
                      C: int, kk: int, dt, axis: str | None):
    """Gather-dispatch + SwiGLU experts + gather-combine.

    xt: [G,T,D] (replicated over the expert/model axis); wk3: [G,k,T] router
    weights; idx3: [G,E_l,C] token-id+1 per slot (0 = empty); sel3/pos3:
    [G,k,T] expert id / slot position per (choice, token); wg/wu/wd:
    [E_l, D, F] local expert shard.  Runs inside shard_map(axis) (manual
    expert axis, TPU) or plain (axis=None, E_l=E, CPU/auto).

    Gather-only formulation: batched scatters of [T,D] update blocks
    partition catastrophically under auto-SPMD (measured: 128 GiB u32
    all-gathers on qwen3 — see EXPERIMENTS.md §Perf); batched gathers with
    sharded index arrays stay local, and the combine gathers straight from
    the expert-sharded [G,E,C+1,D] outputs so the partitioner can use
    masked-gather + partial-sum instead of replicating the slot buffer."""
    manual = axis is not None
    base_e = (lax.axis_index(axis) * E_l) if axis else 0
    G, T, D = xt.shape
    gi = jnp.arange(G)[:, None]

    # dispatch: ein[g,e,c] = xt[g, idx3[g,e,c]-1] (slot 0 -> zero row).
    # All gathers/scatters are vmapped over G so it becomes an HLO operand
    # *batching* dim — indexing G explicitly puts it in the scatter index
    # space, which XLA's partitioner cannot shard (measured: full-batch f32
    # replication + 24 GiB all-gathers per layer; EXPERIMENTS.md §Perf).
    xt_pad = jnp.concatenate([jnp.zeros((G, 1, D), dt), xt.astype(dt)], axis=1)
    if not manual:  # fresh tensors lose the G(data) sharding: re-pin
        xt_pad = constrain(xt_pad, ("batch", None, "embed"))
    ein = jax.vmap(lambda xp, ix: xp[ix])(xt_pad, idx3)  # [G,E_l,C,D]
    if not manual:
        ein = constrain(ein, ("batch", "experts", None, "embed"))
    g = jnp.einsum("gecd,edf->gecf", ein, wg.astype(dt),
                   preferred_element_type=F32).astype(dt)
    u = jnp.einsum("gecd,edf->gecf", ein, wu.astype(dt),
                   preferred_element_type=F32).astype(dt)
    eout = jnp.einsum("gecf,efd->gecd", jax.nn.silu(g) * u, wd.astype(dt),
                      preferred_element_type=F32).astype(dt)
    if not manual:
        eout = constrain(eout, ("batch", "experts", None, "embed"))
    # combine: scatter-ADD each slot's output back to its token (idx3 is the
    # slot->token map; row 0 is the trash row for empty slots).  Wire cost is
    # one [G,T,D] partial-sum merge instead of replicating the full
    # [G,E*C,D] slot buffer (16x fewer bytes at kimi-k2 scale).  Two earlier
    # formulations measured worse — flat-slot gather from a replicated
    # buffer (AG-bound) and (expert,slot)-pair gather (XLA replicates
    # per-gather); see EXPERIMENTS.md §Perf.
    wslot = jnp.zeros((G, E_l * C + 1), F32)
    for j in range(kk):
        e_j, p_j = sel3[:, j], pos3[:, j]
        le = e_j - base_e
        valid = (le >= 0) & (le < E_l) & (p_j < C)
        lidx = jnp.where(valid, le * C + jnp.minimum(p_j, C - 1), E_l * C)
        wslot = jax.vmap(lambda w, ix, u: w.at[ix].add(u))(
            wslot, lidx, wk3[:, j].astype(F32))
    weighted = eout.reshape(G, E_l * C, D) * \
        wslot[:, : E_l * C, None].astype(dt)
    out_pad = jnp.zeros((G, T + 1, D), dt)
    if not manual:
        out_pad = constrain(out_pad, ("batch", None, "embed"))
    idx_flat = idx3.reshape(G, E_l * C)
    out = jax.vmap(lambda op, ix, up: op.at[ix].add(up))(
        out_pad, idx_flat, weighted)[:, 1:]
    if not manual:
        out = constrain(out, ("batch", None, "embed"))
    if axis:
        # f32 psum: the CPU AllReducePromotion pass check-fails on 16-bit
        # all-reduces with non-add combiners (compiler bug); TPU unaffected.
        out = lax.psum(out.astype(F32), axis).astype(dt)
    return out


def moe_forward(cfg: ArchConfig, p: dict, x):
    """x: [B,S,D] -> [B,S,D].  Returns (out, aux_loss)."""
    B, S, D = x.shape
    E, kk = cfg.num_experts, cfg.experts_per_token
    G = max(1, min(cfg.num_moe_groups, B * S))
    T = (B * S) // G
    C = moe_capacity(cfg, T)
    xt = constrain(x.reshape(G, T, D), ("batch", None, "embed"))

    logits = jnp.einsum("gtd,de->gte", xt.astype(F32), p["router"].astype(F32))
    probs = jax.nn.softmax(logits, -1)
    topw, topi = lax.top_k(probs, kk)  # [G,T,k]
    topw = topw / jnp.maximum(topw.sum(-1, keepdims=True), 1e-9)

    # slot assignment with first-choice priority: exclusive cumsum over (k, t)
    dt = cfg.compute_dtype
    ohp = jax.nn.one_hot(topi.transpose(0, 2, 1).reshape(G, kk * T), E,
                         dtype=jnp.int32)  # [G,kT,E] priority-major
    ohp = constrain(ohp, ("batch", None, "experts"))
    pos_all = jnp.cumsum(ohp, axis=1) - ohp  # exclusive, [G,kT,E]
    chosen_pos = (pos_all * ohp).sum(-1)  # [G,kT] slot within chosen expert
    sel = topi.transpose(0, 2, 1).reshape(G, kk * T)

    gidx = jnp.where(chosen_pos < C, sel * C + chosen_pos, E * C)  # E*C=drop

    # invert (token -> slot) into (slot -> token): tiny int32 scatter; the
    # heavy data movement is then gather-only, O(T*k*D).  Both alternatives
    # were measured and rejected (EXPERIMENTS.md §Perf): the one-hot dispatch
    # einsum costs O(T*E*C*D) FLOPs (40x model flops at kimi-k2 scale) and
    # batched [T,D]-block scatters replicate catastrophically under
    # auto-SPMD (128 GiB u32 all-gathers on qwen3).
    tok1 = jnp.tile(jnp.arange(1, T + 1, dtype=jnp.int32)[None], (1, kk))
    tok_of_slot = constrain(jnp.zeros((G, E * C + 1), jnp.int32), ("batch", None))
    tok_of_slot = jax.vmap(lambda t, ix, u: t.at[ix].set(u, mode="drop"))(
        tok_of_slot, gidx, jnp.broadcast_to(tok1, (G, kk * T)))
    idx3 = constrain(tok_of_slot[:, : E * C].reshape(G, E, C),
                     ("batch", "experts", None))
    sel3 = sel.reshape(G, kk, T)
    pos3 = chosen_pos.reshape(G, kk, T)
    wk3 = topw.transpose(0, 2, 1)  # [G,k,T]

    dt = cfg.compute_dtype
    mesh = current_mesh()
    tp = mesh.shape.get("model", 1) if mesh is not None else 1
    if cfg.moe_shard_map and mesh is not None and tp > 1 and E % tp == 0:
        from jax.sharding import PartitionSpec as P
        # ZeRO-3 boundary: explicitly all-gather the FSDP (data-axis) shards
        # of the expert weights *before* the manual region — a data-sharded
        # contraction inside shard_map would otherwise force a cross-data
        # psum per expert GEMM (and trips an XLA CPU promotion-pass bug on
        # the bf16 copy-combiner all-reduce it generates).
        wg_, wu_, wd_ = (constrain(p[k], ("experts", None, None))
                         for k in ("w_gate", "w_up", "w_down"))
        body = functools.partial(_moe_expert_block, E_l=E // tp, C=C, kk=kk,
                                 dt=dt, axis="model")
        fn = tp_shard_map(
            body, mesh,
            (P(), P(), P(None, "model", None), P(), P(), P("model"),
             P("model"), P("model")),
            P())
        out = fn(xt, wk3, idx3, sel3, pos3, wg_, wu_, wd_)
    else:
        out = _moe_expert_block(xt, wk3, idx3, sel3, pos3, p["w_gate"],
                                p["w_up"], p["w_down"], E_l=E, C=C, kk=kk,
                                dt=dt, axis=None)
    out = constrain(out, ("batch", None, "embed"))

    # load-balancing aux loss (Switch): E * sum_e f_e * p_e
    me = probs.mean(axis=(0, 1))  # [E]
    fe = ohp.reshape(G, kk, T, E).sum(1).astype(F32).mean(axis=(0, 1)) / kk
    aux = E * jnp.sum(me * fe)
    return out.reshape(B, S, D), aux
