"""Parameter declaration system.

Models declare their parameters once as a pytree of :class:`ParamSpec`
(shape + logical axes + init kind).  From that single source of truth we
derive:

- ``init_params``      — materialized, deterministically-initialized arrays
- ``shape_tree``       — ``jax.ShapeDtypeStruct`` stand-ins (dry-run, no alloc)
- ``pspec_tree``       — ``PartitionSpec`` per param via the sharding rules

Logical axis names (see ``repro.launch.sharding`` for the mesh mapping):
``vocab embed ffn heads kv_heads qk lora experts state conv layers frontend``
"""
from __future__ import annotations

import math
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class ParamSpec(NamedTuple):
    shape: tuple
    axes: tuple  # logical axis name (or None) per dim
    init: str = "scaled"  # scaled | normal | zeros | ones | ssm_a | dt_bias
    dtype: Any = None  # None -> model param_dtype

    def stacked(self, n: int, axis_name: str = "layers") -> "ParamSpec":
        return ParamSpec((n,) + tuple(self.shape), (axis_name,) + tuple(self.axes),
                         self.init, self.dtype)


def is_spec(x) -> bool:
    return isinstance(x, ParamSpec)


def _init_leaf(spec: ParamSpec, key, default_dtype):
    dtype = spec.dtype or default_dtype
    shape = tuple(int(s) for s in spec.shape)
    if spec.init == "zeros":
        return jnp.zeros(shape, dtype)
    if spec.init == "ones":
        return jnp.ones(shape, dtype)
    if spec.init == "ssm_a":  # A_log ~ log(Uniform[1, 16])
        u = jax.random.uniform(key, shape, jnp.float32, 1.0, 16.0)
        return jnp.log(u).astype(dtype)
    if spec.init == "dt_bias":  # inverse-softplus of Uniform[1e-3, 1e-1]
        u = jax.random.uniform(key, shape, jnp.float32, 1e-3, 1e-1)
        return jnp.log(jnp.expm1(u)).astype(dtype)
    if spec.init == "normal":
        return (0.02 * jax.random.normal(key, shape, jnp.float32)).astype(dtype)
    # "scaled": truncated-normal-ish with 1/sqrt(fan_in); fan_in = product of
    # all dims except the last (the output dim convention used throughout).
    fan_in = max(1, math.prod(shape[:-1]))
    std = 1.0 / math.sqrt(fan_in)
    return (std * jax.random.normal(key, shape, jnp.float32)).astype(dtype)


def init_params(spec_tree, rng, default_dtype=jnp.float32):
    leaves, treedef = jax.tree.flatten(spec_tree, is_leaf=is_spec)
    out = []
    for i, spec in enumerate(leaves):
        out.append(_init_leaf(spec, jax.random.fold_in(rng, i), default_dtype))
    return jax.tree.unflatten(treedef, out)


def shape_tree(spec_tree, default_dtype=jnp.float32):
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(tuple(int(d) for d in s.shape),
                                       s.dtype or default_dtype),
        spec_tree, is_leaf=is_spec)


def axes_tree(spec_tree):
    return jax.tree.map(lambda s: tuple(s.axes), spec_tree, is_leaf=is_spec)


def count_params(spec_tree) -> int:
    leaves = jax.tree.leaves(spec_tree, is_leaf=is_spec)
    return sum(int(math.prod(s.shape)) for s in leaves)


def stack_tree(spec_tree, n: int, axis_name: str = "layers"):
    return jax.tree.map(lambda s: s.stacked(n, axis_name), spec_tree, is_leaf=is_spec)
