"""Mamba-2 SSD (state-space duality) block — pure-JAX reference.

The chunked SSD algorithm re-expresses the selective-SSM recurrence as
block-diagonal GEMMs (intra-chunk) plus a tiny inter-chunk recurrence — i.e.
it is the paper's block-wise GEMM insight applied to SSMs, which is why we use
it (TPU MXU-friendly) for both mamba2-130m and the Jamba hybrid.

Layout: d_inner = expand * d_model, H = d_inner / headdim SSD heads of head
dim P, shared (n_groups=1) B/C of state dim N.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ArchConfig
from repro.models.params import ParamSpec

F32 = jnp.float32


def ssd_specs(cfg: ArchConfig) -> dict:
    D = cfg.d_model
    H, P, N = cfg.ssm_heads, cfg.ssm_headdim, cfg.ssm_state
    W = cfg.ssm_conv_width
    return {
        "w_z": ParamSpec((D, H, P), ("embed", "heads", "qk")),
        "w_x": ParamSpec((D, H, P), ("embed", "heads", "qk")),
        "w_B": ParamSpec((D, N), ("embed", "state")),
        "w_C": ParamSpec((D, N), ("embed", "state")),
        "w_dt": ParamSpec((D, H), ("embed", "heads")),
        "dt_bias": ParamSpec((H,), ("heads",), "dt_bias", jnp.float32),
        "A_log": ParamSpec((H,), ("heads",), "ssm_a", jnp.float32),
        "D_skip": ParamSpec((H,), ("heads",), "ones", jnp.float32),
        "conv_x": ParamSpec((W, H, P), ("conv", "heads", "qk"), "normal"),
        "conv_B": ParamSpec((W, N), ("conv", "state"), "normal"),
        "conv_C": ParamSpec((W, N), ("conv", "state"), "normal"),
        "norm": ParamSpec((H, P), ("heads", "qk"), "ones"),
        "w_out": ParamSpec((H, P, D), ("heads", "qk", "embed")),
    }


def _causal_conv(x, w, state=None):
    """Depthwise causal conv via shifted adds.  x: [B,S,...ch], w: [W,...ch].
    If `state` ([B, W-1, ...ch]) is given, it prefixes x (decode streaming);
    returns (y, new_state)."""
    Wd = w.shape[0]
    if state is not None:
        x = jnp.concatenate([state.astype(x.dtype), x], axis=1)
    S = x.shape[1]
    y = sum(
        jnp.pad(x, [(0, 0), (Wd - 1 - j, 0)] + [(0, 0)] * (x.ndim - 2))[:, : S]
        * w[j]
        for j in range(Wd)
    )
    out = y if state is None else y[:, Wd - 1 :]
    new_state = x[:, -(Wd - 1) :] if Wd > 1 else None
    return out, new_state


def _segsum(x):
    """x: [..., Q] -> lower-triangular cumulative segment sums [..., Q, Q]."""
    Q = x.shape[-1]
    cs = jnp.cumsum(x, -1)
    diff = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((Q, Q), bool))
    return jnp.where(mask, diff, -jnp.inf)


def _proj_inputs(cfg, p, x):
    dt_ = cfg.compute_dtype
    # f32 accumulation on every input projection; z/xs/B/C are stored back
    # in the compute dtype (they feed the bf16 conv/gate path) while dt
    # stays f32 — its only consumer is the f32 softplus/decay chain, so a
    # downcast would just round-trip precision away (analysis rule J002).
    z = jnp.einsum("bsd,dhp->bshp", x, p["w_z"].astype(dt_),
                   preferred_element_type=F32).astype(dt_)
    xs = jnp.einsum("bsd,dhp->bshp", x, p["w_x"].astype(dt_),
                    preferred_element_type=F32).astype(dt_)
    Bm = jnp.einsum("bsd,dn->bsn", x, p["w_B"].astype(dt_),
                    preferred_element_type=F32).astype(dt_)
    Cm = jnp.einsum("bsd,dn->bsn", x, p["w_C"].astype(dt_),
                    preferred_element_type=F32).astype(dt_)
    dt = jnp.einsum("bsd,dh->bsh", x, p["w_dt"].astype(dt_),
                    preferred_element_type=F32)
    return z, xs, Bm, Cm, dt


def ssd_forward(cfg: ArchConfig, p: dict, x, return_cache: bool = False):
    """x: [B,S,D] -> [B,S,D].  S must be a multiple of ssm_chunk (or smaller).
    With ``return_cache``, also returns the streaming state for decode."""
    B_, S, D = x.shape
    H, P, N = cfg.ssm_heads, cfg.ssm_headdim, cfg.ssm_state
    Q = min(cfg.ssm_chunk, S)
    while S % Q:  # largest chunk that divides S (zero-padding would corrupt
        Q -= 1    # the decayed final state)
    nc = S // Q
    W = cfg.ssm_conv_width

    z, xs, Bm, Cm, dt = _proj_inputs(cfg, p, x)
    conv_tails = None
    if return_cache:  # raw pre-conv tails, matching the decode streaming conv
        conv_tails = (xs[:, -(W - 1):], Bm[:, -(W - 1):], Cm[:, -(W - 1):])
    xs, _ = _causal_conv(xs, p["conv_x"].astype(xs.dtype))
    Bm, _ = _causal_conv(Bm, p["conv_B"].astype(Bm.dtype))
    Cm, _ = _causal_conv(Cm, p["conv_C"].astype(Cm.dtype))
    xs, Bm, Cm = jax.nn.silu(xs), jax.nn.silu(Bm), jax.nn.silu(Cm)

    dt = jax.nn.softplus(dt.astype(F32) + p["dt_bias"].astype(F32))  # [B,S,H]
    A = -jnp.exp(p["A_log"].astype(F32))  # [H]

    # chunk
    xc = xs.reshape(B_, nc, Q, H, P)
    Bc = Bm.reshape(B_, nc, Q, N)
    Cc = Cm.reshape(B_, nc, Q, N)
    dtc = dt.reshape(B_, nc, Q, H)
    dA = dtc * A  # [B,nc,Q,H]
    dA_cs = jnp.cumsum(dA, axis=2)

    xdt = xc * dtc[..., None].astype(xc.dtype)

    # intra-chunk (block-diagonal GEMMs)
    L = jnp.exp(_segsum(dA.transpose(0, 3, 1, 2)))  # [B,H,nc,Q,Q]
    y_diag = jnp.einsum("bcln,bcsn,bhcls,bcshp->bclhp",
                        Cc.astype(F32), Bc.astype(F32), L,
                        xdt.astype(F32))

    # chunk-final states
    decay = jnp.exp(dA_cs[:, :, -1:, :] - dA_cs)  # [B,nc,Q,H]
    states = jnp.einsum("bcsn,bcsh,bcshp->bchpn",
                        Bc.astype(F32), decay, xdt.astype(F32))

    # inter-chunk recurrence (tiny sequential scan over nc)
    chunk_decay = jnp.exp(dA_cs[:, :, -1, :])  # [B,nc,H]

    def step(h, inp):
        s, d = inp  # s: [B,H,P,N], d: [B,H]
        h_new = h * d[..., None, None] + s
        return h_new, h

    init = jnp.zeros((B_, H, P, N), F32)
    h_final, h_prev = lax.scan(step, init,
                               (states.transpose(1, 0, 2, 3, 4),
                                chunk_decay.transpose(1, 0, 2)))
    h_prev = h_prev.transpose(1, 0, 2, 3, 4)  # [B,nc,H,P,N] state entering chunk

    # inter-chunk contribution
    in_decay = jnp.exp(dA_cs)  # [B,nc,Q,H]
    y_off = jnp.einsum("bcln,bclh,bchpn->bclhp", Cc.astype(F32), in_decay, h_prev)

    y = (y_diag + y_off).astype(cfg.compute_dtype)
    y = y + xc * p["D_skip"].astype(cfg.compute_dtype)[:, None]
    y = y.reshape(B_, S, H, P)
    y = y * jax.nn.silu(z)
    # gated RMSNorm over (H,P)
    yf = y.astype(F32)
    yf = yf * lax.rsqrt(jnp.mean(yf * yf, axis=(-2, -1), keepdims=True) + 1e-6)
    y = (yf * p["norm"].astype(F32)).astype(cfg.compute_dtype)
    out = jnp.einsum("bshp,hpd->bsd", y, p["w_out"].astype(cfg.compute_dtype),
                     preferred_element_type=F32).astype(cfg.compute_dtype)
    if return_cache:
        cx, cB, cC = conv_tails
        return out, {"h": h_final, "conv_x": cx, "conv_B": cB, "conv_C": cC}
    return out


# ---------------------------------------------------------------------------
# streaming (decode) path
# ---------------------------------------------------------------------------

def ssd_cache_specs(cfg: ArchConfig, batch: int) -> dict:
    H, P, N = cfg.ssm_heads, cfg.ssm_headdim, cfg.ssm_state
    W = cfg.ssm_conv_width
    return {
        "h": ParamSpec((batch, H, P, N), ("batch", "heads", "qk", "state"),
                       "zeros", jnp.float32),
        "conv_x": ParamSpec((batch, W - 1, H, P), ("batch", "conv", "heads", "qk"), "zeros"),
        "conv_B": ParamSpec((batch, W - 1, N), ("batch", "conv", "state"), "zeros"),
        "conv_C": ParamSpec((batch, W - 1, N), ("batch", "conv", "state"), "zeros"),
    }


def ssd_decode(cfg: ArchConfig, p: dict, cache: dict, x):
    """Single-token state update.  x: [B,1,D]."""
    z, xs, Bm, Cm, dt = _proj_inputs(cfg, p, x)
    xs, cx = _causal_conv(xs, p["conv_x"].astype(xs.dtype), cache["conv_x"])
    Bm, cB = _causal_conv(Bm, p["conv_B"].astype(Bm.dtype), cache["conv_B"])
    Cm, cC = _causal_conv(Cm, p["conv_C"].astype(Cm.dtype), cache["conv_C"])
    xs, Bm, Cm = jax.nn.silu(xs), jax.nn.silu(Bm), jax.nn.silu(Cm)

    dt = jax.nn.softplus(dt.astype(F32) + p["dt_bias"].astype(F32))[:, 0]  # [B,H]
    A = -jnp.exp(p["A_log"].astype(F32))
    dA = jnp.exp(dt * A)  # [B,H]
    h = cache["h"] * dA[..., None, None] + jnp.einsum(
        "bn,bh,bhp->bhpn", Bm[:, 0].astype(F32), dt, xs[:, 0].astype(F32))
    y = jnp.einsum("bn,bhpn->bhp", Cm[:, 0].astype(F32), h)
    y = y + xs[:, 0].astype(F32) * p["D_skip"].astype(F32)[:, None]
    y = y[:, None].astype(cfg.compute_dtype) * jax.nn.silu(z)
    yf = y.astype(F32)
    yf = yf * lax.rsqrt(jnp.mean(yf * yf, axis=(-2, -1), keepdims=True) + 1e-6)
    y = (yf * p["norm"].astype(F32)).astype(cfg.compute_dtype)
    out = jnp.einsum("bshp,hpd->bsd", y, p["w_out"].astype(cfg.compute_dtype),
                     preferred_element_type=F32).astype(cfg.compute_dtype)
    return out, {"h": h, "conv_x": cx, "conv_B": cB, "conv_C": cC}
