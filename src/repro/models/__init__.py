from repro.models import layers, model, params, ssd  # noqa: F401
from repro.models.model import (  # noqa: F401
    cache_shapes,
    cache_specs,
    decode_step,
    init,
    init_cache,
    loss_fn,
    param_shapes,
    param_specs,
    prefill,
)
