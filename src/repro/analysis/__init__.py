"""Static kernel-contract & config-rot checker for the serving stack.

``python -m repro.analysis --strict`` traces every shipped config through
the real serving entry points and proves the Pallas BlockSpec contracts —
see DESIGN.md §8 for the rule catalogue and ``repro.analysis.findings.RULES``
for the machine-readable list."""
from repro.analysis.bounds import check_kernel_spec
from repro.analysis.donation import check_donation
from repro.analysis.findings import RULES, Finding, Report
from repro.analysis.hlo_lints import lint_hlo, param_gather_shapes
from repro.analysis.jaxpr_lints import (check_logits_dtype, iter_jaxprs,
                                        lint_jaxpr)
from repro.analysis.runner import (MODES, QUANTS, analysis_config, check_cell,
                                   check_kernels, check_paging,
                                   check_resilience, check_sharded,
                                   run_analysis)

__all__ = [
    "RULES", "Finding", "Report",
    "check_kernel_spec", "check_donation", "check_logits_dtype",
    "iter_jaxprs", "lint_jaxpr", "lint_hlo", "param_gather_shapes",
    "MODES", "QUANTS", "analysis_config", "check_cell", "check_kernels",
    "check_paging", "check_resilience", "check_sharded", "run_analysis",
]
