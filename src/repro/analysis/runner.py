"""Drive the static checks over every shipped config.

For each (config, kernel_mode, quant) cell the runner *traces* — never
executes — the real serving entry points (``jax.make_jaxpr`` on the same
bound methods the engine jits) and walks the jaxprs with the J-rules, checks
buffer donation on the jitted surfaces (D-rules), proves the BlockSpec
contracts of every Pallas kernel the config can reach (K-rules, via the
kernels' introspectable ``KernelSpec``), and exercises the paging
bookkeeping against ``paging.check_invariants`` (P001).

Configs are shrunk with ``reduce_config`` for trace speed but keep their
*shipped* dtypes (``reduce_config`` forces f32, which would hide every
promotion bug this tool exists to catch) and the requested kernel mode and
quantization."""
from __future__ import annotations

import functools
from typing import Iterable, Optional, Sequence

import jax
import jax.numpy as jnp

from repro.analysis.bounds import check_kernel_spec
from repro.analysis.donation import check_donation
from repro.analysis.findings import Finding, Report
from repro.analysis.hlo_lints import lint_hlo, param_gather_shapes
from repro.analysis.jaxpr_lints import check_logits_dtype, lint_jaxpr
from repro.configs import REGISTRY, get_config, reduce_config
from repro.models import model as M
from repro.serving.config import EngineConfig
from repro.serving.engine import Engine
from repro.serving.paging import PagePool, RadixCache, check_invariants

MODES = ("reference", "interpret")
QUANTS = ("none", "w8a8")

# trace geometry: small enough to trace fast, big enough to exercise every
# structural path (window=32 after reduce_config, one page table per seq)
_S = 32          # forward / prefill sequence length
_B = 2           # batch
_ENGINE = dict(page_size=16, max_batch=2, max_len=64, decode_chunk=2)


def analysis_config(name: str, mode: str, quant: str):
    """Reduced config with the *shipped* dtypes / kernel mode / quant.

    ``reduce_config`` forces f32 params+compute for numeric smoke tests;
    the checker restores the original dtypes — a bf16 serving stack traced
    in f32 would show none of the promotions the J-rules look for."""
    full = get_config(name)
    return reduce_config(full).with_(
        param_dtype=full.param_dtype,
        compute_dtype=full.compute_dtype,
        kernel_mode=mode,
        quant=quant,
    )


def _batch(cfg, B: int = _B, S: int = _S, labels: bool = False) -> dict:
    rng = jax.random.PRNGKey(0)
    batch = {"tokens": jax.random.randint(rng, (B, S), 0, cfg.vocab_size)}
    if labels:
        batch["labels"] = batch["tokens"]
    if cfg.audio_frontend:
        batch["frames"] = jnp.zeros((B, S, cfg.frontend_dim), jnp.float32)
    if cfg.vision_tokens:
        batch["images"] = jnp.zeros((B, cfg.vision_tokens, cfg.vision_dim),
                                    jnp.float32)
    return batch


def _lint_entry(report: Report, fn, args, ctx: str, *, logits: bool = False,
                donate: Optional[tuple] = None) -> None:
    """Trace one entry point and run the J (and optionally D/J006) rules."""
    closed = jax.make_jaxpr(fn)(*args)
    report.extend(lint_jaxpr(closed, ctx))
    if logits:
        report.extend(check_logits_dtype(closed.jaxpr.outvars[0].aval, ctx))
    if donate is not None:
        report.extend(check_donation(fn, args, donate, ctx))
    report.checked.append(ctx)


def check_cell(name: str, mode: str, quant: str, report: Report,
               params=None) -> None:
    """All jaxpr/donation checks for one (config, mode, quant) cell."""
    cfg = analysis_config(name, mode, quant)
    base = f"config={name} mode={mode} quant={quant}"
    if params is None:
        params = M.init(cfg, jax.random.PRNGKey(0))

    # forward (train) entry — every config, encoder included
    fwd_params = (M.quantize_params(cfg, params) if quant == "w8a8"
                  else params)

    def fwd(p, batch):
        hidden, _, _ = M.forward_hidden(cfg, p, batch, mode="train")
        return M.lm_logits(cfg, p, hidden)

    _lint_entry(report, fwd, (fwd_params, _batch(cfg)),
                f"{base} entry=forward", logits=True)

    if cfg.kind != "decoder":
        return

    # serving entries, traced exactly as the engine jits them
    eng = Engine(cfg, params, EngineConfig(kernel_mode=mode, quant=quant,
                                           **_ENGINE))
    runner, npp = eng.runner, eng.npp
    caches = runner.caches
    pages = jnp.zeros((_B, npp), jnp.int32)
    cur = jnp.zeros(_B, jnp.int32)
    pos = jnp.zeros(_B, jnp.int32)
    remaining = jnp.zeros(_B, jnp.int32)
    temp = jnp.zeros(_B, jnp.float32)
    keys = jnp.zeros((_B, 2), jnp.uint32)

    def pfx(p, batch):
        return M.prefill(eng.cfg, p, batch, full_kv=True)[0]

    _lint_entry(report, pfx, (runner.params, _batch(eng.cfg)),
                f"{base} entry=prefill", logits=True)

    nanmask = jnp.zeros(_B, jnp.bool_)
    dec_args = (runner.params, caches, pages, cur, pos, remaining, temp, keys,
                nanmask)
    _lint_entry(report, runner._decode_chunk, dec_args,
                f"{base} entry=decode", donate=(1,))
    report.extend(check_logits_dtype(
        jax.eval_shape(lambda: M.decode_step(
            eng.cfg, runner.params, caches, cur[:, None], pos,
            pages=pages)[0]),
        f"{base} entry=decode"))

    _lint_entry(report, runner._copy_page,
                (caches, jnp.int32(1), jnp.int32(2)),
                f"{base} entry=copy_page", donate=(0,))

    if eng.sched.chunked:
        C = 8
        mixed_args = (runner.params, caches, jnp.zeros((1, C), jnp.int32),
                      pages[:1], jnp.int32(0), jnp.int32(C), jnp.float32(0.0),
                      keys[0], jnp.bool_(False), pages, cur, pos, remaining,
                      temp, keys, nanmask)
        _lint_entry(report, runner._mixed, mixed_args,
                    f"{base} entry=mixed", donate=(1,))
    elif all(sp.mixer != "cross" for sp in eng.cfg.layer_specs()):
        n = 8
        wp_args = (runner.params, caches, jnp.zeros((1, n), jnp.int32),
                   jnp.zeros(npp, jnp.int32), jnp.int32(0), jnp.float32(0.0),
                   keys[0])
        _lint_entry(report, functools.partial(runner._whole_prefill, n),
                    wp_args, f"{base} entry=whole_prefill", donate=(1,))
    else:
        # cross-attention prefill requires the image batch, which the
        # engine's tokens-only whole-prompt path cannot supply — the model's
        # prefill surface is covered above (entry=prefill traces M.prefill
        # with images)
        report.checked.append(f"{base} entry=whole_prefill (skipped: "
                              f"cross-attn prefill needs images)")


def check_sharded(name: str, report: Report, params=None) -> None:
    """Sharded-surface checks (J007 + the J/D rules on mesh traces).

    Builds the engine on a ``1xT`` model-parallel mesh over the host's
    devices, traces the serving executables with the mesh context active
    (so the jaxprs carry the real sharding constraints), and compiles the
    decode and prefill executables to run the J007 HLO lint — all-gathers
    only exist after SPMD partitioning, so the jaxpr rules cannot see
    them.  Reference mode / no quant only: kernel modes share the same
    placement code, and the compiled-module check is about *sharding*,
    not kernel internals.  Skipped (with a note) on single-device hosts;
    the multi-device CI lane forces 8 host devices."""
    dc = jax.device_count()
    cfg = analysis_config(name, "reference", "none")
    if cfg.kind != "decoder":
        return
    if dc < 2:
        report.checked.append(
            f"config={name} sharded surfaces (skipped: 1 device; set "
            f"XLA_FLAGS=--xla_force_host_platform_device_count=8)")
        return
    tp = 8 if dc >= 8 else (4 if dc >= 4 else 2)
    base = f"config={name} mesh=1x{tp}"
    if params is None:
        params = M.init(cfg, jax.random.PRNGKey(0))

    eng = Engine(cfg, params, EngineConfig(kernel_mode="reference",
                                           quant="none", mesh=f"1x{tp}",
                                           **_ENGINE))
    runner, npp = eng.runner, eng.npp
    caches = runner.caches
    pages = jnp.zeros((_B, npp), jnp.int32)
    cur = jnp.zeros(_B, jnp.int32)
    pos = jnp.zeros(_B, jnp.int32)
    remaining = jnp.zeros(_B, jnp.int32)
    temp = jnp.zeros(_B, jnp.float32)
    keys = jnp.zeros((_B, 2), jnp.uint32)
    shapes = param_gather_shapes(runner.params)

    nanmask = jnp.zeros(_B, jnp.bool_)
    dec_args = (runner.params, caches, pages, cur, pos, remaining, temp, keys,
                nanmask)
    _lint_entry(report, runner._traced(runner._decode_chunk), dec_args,
                f"{base} entry=decode", donate=(1,))
    hlo = runner.decode_fn.lower(*dec_args).compile().as_text()
    report.extend(lint_hlo(hlo, shapes, f"{base} entry=decode hlo"))
    report.checked.append(f"{base} entry=decode hlo")

    if eng.sched.chunked:
        C = 8
        mixed_args = (runner.params, caches, jnp.zeros((1, C), jnp.int32),
                      pages[:1], jnp.int32(0), jnp.int32(C), jnp.float32(0.0),
                      keys[0], jnp.bool_(False), pages, cur, pos, remaining,
                      temp, keys, nanmask)
        _lint_entry(report, runner._traced(runner._mixed), mixed_args,
                    f"{base} entry=mixed", donate=(1,))
        hlo = runner.mixed_fn(C, 1).lower(*mixed_args).compile().as_text()
        report.extend(lint_hlo(hlo, shapes, f"{base} entry=mixed hlo"))
        report.checked.append(f"{base} entry=mixed hlo")
    elif all(sp.mixer != "cross" for sp in eng.cfg.layer_specs()):
        n = 8
        wp_args = (runner.params, caches, jnp.zeros((1, n), jnp.int32),
                   jnp.zeros(npp, jnp.int32), jnp.int32(0), jnp.float32(0.0),
                   keys[0])
        _lint_entry(report,
                    runner._traced(functools.partial(runner._whole_prefill,
                                                     n)),
                    wp_args, f"{base} entry=whole_prefill", donate=(1,))
        hlo = runner.whole_prefill_fn(n, 1).lower(*wp_args).compile() \
                    .as_text()
        report.extend(lint_hlo(hlo, shapes, f"{base} entry=whole_prefill "
                                            f"hlo"))
        report.checked.append(f"{base} entry=whole_prefill hlo")


def check_kernels(name: str, report: Report) -> None:
    """K-rule bounds proofs for every kernel the config can reach.

    Mode/quant-independent: the specs describe grid/index-map geometry,
    which is fixed by the architecture + engine cache geometry."""
    from repro.kernels.block_gemm import gemm_spec
    from repro.kernels.decode_attention import fd_dense_spec, fd_paged_spec
    from repro.kernels.flash_attention import fa_dense_spec, fa_paged_spec

    cfg = analysis_config(name, "reference", "none")
    ctx = f"config={name}"
    ec = EngineConfig(**_ENGINE)
    ps, npp, n_pages = ec.page_size, ec.cache_spec().pages_per_seq, ec.n_pages

    specs = [gemm_spec(cfg.d_model, cfg.d_model, cfg.vocab_size),
             gemm_spec(cfg.d_model, cfg.d_model, cfg.vocab_size, int8=True)]
    mixers = {sp.mixer for sp in cfg.layer_specs()}
    if any(m.startswith("attn") or m == "cross" for m in mixers):
        H, K, d = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
        specs.append(fa_dense_spec(_B, H, K, _S, _S, d))
        if cfg.kind == "decoder":
            specs.append(fa_paged_spec(_B, H, K, ps, d, ps, npp, n_pages))
            specs.append(fd_dense_spec(_B, H, K, ec.max_len, d, d,
                                       layout="linear"))
            if cfg.window_size:
                specs.append(fd_dense_spec(_B, H, K, cfg.window_size, d, d,
                                           layout="ring"))
            specs.append(fd_paged_spec(_B, H, K, d, d, ps, npp, n_pages))
    for spec in specs:
        report.extend(check_kernel_spec(spec, ctx))
        report.checked.append(f"{ctx} kernel={spec.name}")


def check_paging(report: Report) -> None:
    """P001: run a deterministic alloc/share/evict workload and verify the
    structural invariants at every quiescent point."""
    ctx = "paging workload"

    def verify(step: str, pool, radix=None, tables=None) -> None:
        for msg in check_invariants(pool, radix, tables):
            report.add(Finding("P001", msg, f"{ctx} step={step}"))

    pool = PagePool(12)
    radix = RadixCache(4, pool)
    verify("init", pool, radix, [])

    # request A: 3 pages, publishes 2 full pages to the tree
    a = [pool.alloc() for _ in range(3)]
    toks_a = list(range(8))
    radix.insert(toks_a, a[:2])
    tables = [a]
    verify("insert", pool, radix, tables)

    # request B: full prefix hit on A's pages + one fresh page
    m = radix.match(toks_a + [9, 9, 9, 9], max_match=11)
    for pid in m.full_pages:
        pool.incref(pid)
    b = list(m.full_pages) + [pool.alloc()]
    tables.append(b)
    verify("match", pool, radix, tables)

    # retire A: tree keeps its pages alive at refcount >= 1
    for pid in a:
        pool.decref(pid)
    tables.remove(a)
    verify("retire", pool, radix, tables)

    # evict everything evictable, then drop the tree outright
    radix.evict(pool.n_pages)
    verify("evict", pool, radix, tables)
    radix.clear()
    for pid in b:
        pool.decref(pid)
    tables.remove(b)
    verify("clear", pool, radix, tables)
    report.checked.append(ctx)


def check_resilience(report: Report) -> None:
    """R001: every ``FinishReason`` branch in the Scheduler is reachable.

    Drives a tiny *executed* (not traced) engine on the reduced edge config
    through one canonical scenario per finish reason — healthy STOP/LENGTH,
    then deadline expiry (chaos-skewed clock), cancellation, bounded-queue
    rejection, preemption under page pressure (``preemption="drop"``) and
    NaN fault isolation — and reports a finding for any reason that never
    surfaces, plus any resilience counter that failed to move.  This is the
    rot check for the degraded-mode state machine: a refactor that silently
    disconnects one of these paths (e.g. ``expire`` never called, ``cancel``
    not wired through) fails here even if no unit test covers it."""
    from repro.serving import ChaosInjector
    from repro.serving.engine import FinishReason

    ctx = "resilience scenarios"
    cfg = reduce_config(get_config("cgra-edge"))  # f32: executed, not traced
    params = M.init(cfg, jax.random.PRNGKey(0))
    ec = dict(page_size=16, max_batch=2, max_len=64, decode_chunk=2,
              prefix_cache=False)
    prompt = list(range(1, 9))
    seen: set[FinishReason] = set()
    stats_hits: set[str] = set()

    def note(eng, results):
        seen.update(r.finish_reason for r in results)
        for f in ("preempted", "rejected", "deadline_expired", "cancelled",
                  "faults_isolated"):
            if getattr(eng.stats, f) > 0:
                stats_hits.add(f)

    # STOP needs a token the model really emits: probe it greedily first
    eng = Engine(cfg, params, EngineConfig(**ec))
    eng.submit(prompt, max_new=2)
    probe = eng.run()
    note(eng, probe)  # LENGTH (max_new exhausted, no eos configured)
    first = probe[0].generated[0]

    eng = Engine(cfg, params, EngineConfig(eos_id=first, **ec))
    eng.submit(prompt, max_new=4)
    note(eng, eng.run())  # STOP (first sampled token is the eos)

    # DEADLINE: the chaos clock jumps +1000s before the first tick
    chaos = ChaosInjector(schedule={"clock.skew": {0}}, skew_s=1000.0)
    eng = Engine(cfg, params, EngineConfig(**ec), chaos=chaos)
    eng.submit(prompt, max_new=4, deadline_s=5.0)
    note(eng, eng.run())

    # CANCELLED (queued) + REJECTED (queue bound 1)
    eng = Engine(cfg, params, EngineConfig(max_queue=1, **ec))
    rid = eng.submit(prompt, max_new=4)
    eng.submit(list(prompt), max_new=4)  # overflows the bound
    eng.cancel(rid)
    note(eng, eng.run())

    # PREEMPTED: two requests oversubscribe a 3-usable-page pool in "drop"
    eng = Engine(cfg, params, EngineConfig(n_pages=4, preemption="drop",
                                           **ec))
    eng.submit(list(range(1, 17)), max_new=20)
    eng.submit(list(range(2, 18)), max_new=20)
    note(eng, eng.run())

    # FAULT: poison the first compiled step's logits
    chaos = ChaosInjector(schedule={"logits.nan": {0}})
    eng = Engine(cfg, params, EngineConfig(**ec), chaos=chaos)
    eng.submit(prompt, max_new=4)
    note(eng, eng.run())

    for reason in FinishReason:
        if reason not in seen:
            report.add(Finding(
                "R001", f"FinishReason.{reason.name} was never produced by "
                        f"its canonical scenario", ctx))
    for f in ("preempted", "rejected", "deadline_expired", "cancelled",
              "faults_isolated"):
        if f not in stats_hits:
            report.add(Finding(
                "R001", f"ServeStats.{f} never incremented across the "
                        f"scenario suite", ctx))
    report.checked.append(ctx)


def run_analysis(configs: Optional[Sequence[str]] = None,
                 modes: Iterable[str] = MODES,
                 quants: Iterable[str] = QUANTS,
                 disabled: Iterable[str] = (),
                 progress=None) -> Report:
    """The full matrix: every named config x kernel mode x quant."""
    report = Report(disabled=sorted(disabled))
    names = list(configs) if configs else sorted(REGISTRY)
    for name in names:
        get_config(name)  # fail fast on typos
    for name in names:
        params = None
        for mode in modes:
            for quant in quants:
                if progress:
                    progress(f"tracing {name} mode={mode} quant={quant}")
                if params is None:
                    params = M.init(analysis_config(name, mode, quant),
                                    jax.random.PRNGKey(0))
                check_cell(name, mode, quant, report, params=params)
        if progress:
            progress(f"kernel bounds {name}")
        check_kernels(name, report)
        if progress and jax.device_count() >= 2:
            progress(f"sharded surfaces {name}")
        check_sharded(name, report, params=params)
    check_paging(report)
    if progress:
        progress("resilience scenarios")
    check_resilience(report)
    return report
