"""CLI: ``python -m repro.analysis [--strict] [--json PATH] ...``.

Exit code 0 == clean (under ``--strict``, *any* finding fails; otherwise
only ``severity == "error"`` findings do)."""
from __future__ import annotations

import argparse
import sys


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="Static kernel-contract & config-rot checker: jaxpr "
                    "lints, donation checks, BlockSpec bounds proofs, and "
                    "paging invariants over every shipped config.")
    ap.add_argument("--configs", default=None,
                    help="comma-separated config names (default: all)")
    ap.add_argument("--modes", default=None,
                    help="comma-separated kernel modes "
                         "(default: reference,interpret)")
    ap.add_argument("--quants", default=None,
                    help="comma-separated quant modes (default: none,w8a8)")
    ap.add_argument("--disable", action="append", default=[], metavar="RULE",
                    help="disable a rule id (repeatable)")
    ap.add_argument("--strict", action="store_true",
                    help="fail on any finding, warnings included")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write the full report as JSON")
    ap.add_argument("--list-rules", action="store_true",
                    help="print the rule catalogue and exit")
    ap.add_argument("-q", "--quiet", action="store_true",
                    help="suppress per-cell progress lines")
    args = ap.parse_args(argv)

    # import after arg parsing so ``--list-rules``/``--help`` stay instant
    from repro.analysis.findings import RULES
    if args.list_rules:
        for rule, desc in sorted(RULES.items()):
            print(f"{rule}  {desc}")
        return 0
    for rule in args.disable:
        if rule not in RULES:
            ap.error(f"unknown rule {rule!r}; see --list-rules")

    from repro.analysis.runner import run_analysis
    progress = None if args.quiet else (
        lambda msg: print(f"[analysis] {msg}", file=sys.stderr, flush=True))
    report = run_analysis(
        configs=args.configs.split(",") if args.configs else None,
        modes=args.modes.split(",") if args.modes else ("reference",
                                                        "interpret"),
        quants=args.quants.split(",") if args.quants else ("none", "w8a8"),
        disabled=args.disable,
        progress=progress)

    for f in report.findings:
        print(f)
    if args.json:
        report.dump(args.json)
    n = len(report.findings)
    print(f"[analysis] {len(report.checked)} surfaces checked, "
          f"{n} finding{'s' if n != 1 else ''}"
          + (f", disabled: {','.join(report.disabled)}"
             if report.disabled else ""))
    return report.exit_code(strict=args.strict)


if __name__ == "__main__":
    sys.exit(main())
