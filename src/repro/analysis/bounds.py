"""BlockSpec bounds prover (rules K001-K003).

Every Pallas kernel exposes its grid / index-map construction as a
:class:`repro.kernels.spec.KernelSpec`.  The prover enumerates the full grid
(vectorized — all grid points at once as numpy index arrays) against
worst-case scalar-prefetch operands drawn from each ``ScalarSpec``'s hostile
domain, and checks:

K001  every index map returns, for every grid point and scalar combination,
      a block index inside ``[0, grid_blocks[d])`` per dimension, and never
      reads a scalar table out of bounds (table reads go through a guarded
      wrapper — numpy would silently wrap negative indices);
K002  along the innermost grid axis, the number of DMAs (1 + index
      transitions — Pallas elides the copy when consecutive steps map to
      the same block) never exceeds the ``pl.when``-live block count: dead
      blocks must be remapped onto live indices, not merely masked;
K003  output index maps are invariant along the declared reduction axes
      (otherwise partial accumulator states are stored per step).
"""
from __future__ import annotations

import itertools
from typing import Any, List, Sequence

import numpy as np

from repro.analysis.findings import Finding
from repro.kernels.spec import KernelSpec, OperandSpec, ScalarSpec


class _GuardedTable:
    """Array wrapper whose ``__getitem__`` bounds-checks every index.

    Index maps read scalar-prefetch operands with computed indices
    (``pages_ref[b, ik]``); numpy would wrap negatives silently and only
    raise past the end.  The guard records any violation and clips so
    evaluation can continue and surface further findings."""

    def __init__(self, name: str, arr: np.ndarray, oob: List[str]):
        self.name = name
        self.arr = arr
        self.oob = oob

    def __getitem__(self, idx: Any) -> np.ndarray:
        parts = idx if isinstance(idx, tuple) else (idx,)
        clipped = []
        for axis, part in enumerate(parts):
            ix = np.asarray(part)
            dim = self.arr.shape[axis]
            if ix.size and (int(ix.min()) < 0 or int(ix.max()) >= dim):
                self.oob.append(
                    f"scalar table '{self.name}' read out of bounds on axis "
                    f"{axis}: index range [{int(ix.min())}, {int(ix.max())}]"
                    f" vs dim {dim}")
            clipped.append(np.clip(ix, 0, dim - 1))
        return self.arr[tuple(clipped)]


def _scalar_candidates(spec: ScalarSpec) -> List[np.ndarray]:
    """Worst-case fills of one scalar operand.  Uniform fills cover the
    domain extremes pointwise; for multi-dim tables two spreads (ascending /
    descending distinct entries) exercise index *transitions* (K002)."""
    lo, hi = spec.lo, spec.hi
    vals = sorted({lo, min(lo + 1, hi), (lo + hi) // 2, max(hi - 1, lo), hi})
    cands = [np.full(spec.shape, v, np.int64) for v in vals]
    if hi > lo and len(spec.shape) > 1:
        span = hi - lo + 1
        flat = np.arange(int(np.prod(spec.shape)), dtype=np.int64)
        cands.append((flat % span + lo).reshape(spec.shape))
        cands.append((flat[::-1] % span + lo).reshape(spec.shape))
    return cands


def _eval_map(op: OperandSpec, grid_ids: Sequence[np.ndarray],
              scalars: Sequence[_GuardedTable]) -> np.ndarray:
    """Index map over every grid point at once -> [n_points, n_dims] int."""
    res = op.index_map(*grid_ids, *scalars)
    n = grid_ids[0].size
    cols = []
    for d, comp in enumerate(res):
        arr = np.asarray(comp)
        if not np.issubdtype(arr.dtype, np.integer):
            raise TypeError(f"index map of '{op.name}' returned non-integer "
                            f"dtype {arr.dtype} for dim {d}")
        cols.append(np.broadcast_to(arr, (n,)).astype(np.int64))
    return np.stack(cols, axis=-1)


def check_kernel_spec(spec: KernelSpec, context: str = "") -> List[Finding]:
    """Run K001-K003 over one kernel instantiation."""
    out: List[Finding] = []
    ctx = f"{context} kernel={spec.name}" if context else f"kernel={spec.name}"
    grid_ids = [ix.reshape(-1) for ix in np.indices(spec.grid)]
    n = grid_ids[0].size

    combos = itertools.product(*(_scalar_candidates(s) for s in spec.scalars))
    seen_rules: set = set()  # dedupe identical findings across combos

    def emit(rule: str, msg: str) -> None:
        key = (rule, msg)
        if key not in seen_rules:
            seen_rules.add(key)
            out.append(Finding(rule, msg, ctx, spec.src_file, spec.src_line))

    for combo in combos:
        oob: List[str] = []
        tables = [_GuardedTable(s.name, arr, oob)
                  for s, arr in zip(spec.scalars, combo)]
        raw = [t.arr for t in tables]
        per_op: dict = {}
        for op in spec.operands:
            try:
                idx = _eval_map(op, grid_ids, tables)
            except Exception as exc:  # map crashed outright
                emit("K001", f"index map of '{op.name}' failed to evaluate: "
                             f"{type(exc).__name__}: {exc}")
                continue
            per_op[op.name] = (op, idx)
            for d in range(idx.shape[1]):
                lo_d, hi_d = int(idx[:, d].min()), int(idx[:, d].max())
                if lo_d < 0 or hi_d >= op.grid_blocks[d]:
                    emit("K001",
                         f"index map of '{op.name}' returns block index in "
                         f"[{lo_d}, {hi_d}] for dim {d} (valid: [0, "
                         f"{op.grid_blocks[d]}))")
        for msg in oob:
            emit("K001", msg)

        # K002: DMA count vs live count along the innermost grid axis
        if spec.block_live is not None and len(spec.grid) > 1:
            inner = spec.grid[-1]
            live = np.broadcast_to(
                np.asarray(spec.block_live(*grid_ids, *raw), bool), (n,))
            live_rows = live.reshape(-1, inner).sum(axis=1)
            for op, idx in per_op.values():
                if op.is_output:
                    continue  # outputs accumulate in VMEM, stored once
                rows = idx.reshape(-1, inner, idx.shape[1])
                dma = 1 + (rows[:, 1:] != rows[:, :-1]).any(-1).sum(axis=1)
                bound = np.maximum(live_rows, 1)
                if (dma > bound).any():
                    i = int(np.argmax(dma > bound))
                    emit("K002",
                         f"'{op.name}' issues {int(dma[i])} DMAs along the "
                         f"innermost axis of grid row {i} but only "
                         f"{int(live_rows[i])} blocks are live — dead "
                         f"blocks must remap to a live index so the "
                         f"revisit copy is elided")

        # K003: output maps invariant along reduction axes
        for op, idx in per_op.values():
            if not op.is_output or not spec.reduction_axes:
                continue
            cube = idx.reshape(*spec.grid, idx.shape[1])
            for axis in spec.reduction_axes:
                if (cube.max(axis=axis) != cube.min(axis=axis)).any():
                    emit("K003",
                         f"output map of '{op.name}' varies along reduction "
                         f"grid axis {axis} — partial accumulator states "
                         f"would be stored per step")
    return out
