"""Donation checks (rules D001/D002): structural multiset comparison of the
donated input buffers of a jitted executable against its output buffers.

XLA reuses a donated input for an output only when some output has the same
(shape, dtype); a donated buffer with no structural match is *dead* — the
caller gave up the buffer and XLA allocates a fresh output anyway (silently,
modulo a warning the serving loop never surfaces).  More donated buffers of
one signature than outputs that can absorb them is the duplicate case."""
from __future__ import annotations

from collections import Counter
from typing import Any, Callable, List, Sequence, Tuple

import jax

from repro.analysis.findings import Finding


def _sig_counts(tree: Any) -> Counter:
    leaves = jax.tree.leaves(tree)
    return Counter((tuple(leaf.shape), str(leaf.dtype)) for leaf in leaves)


def check_donation(fn: Callable, args: Sequence[Any],
                   donate_argnums: Tuple[int, ...],
                   context: str = "") -> List[Finding]:
    """Compare donated-arg leaf signatures against ``fn``'s output leaves.

    ``args`` may be concrete arrays or ``ShapeDtypeStruct``s — only shapes
    are consumed (`jax.eval_shape` does the tracing)."""
    out: List[Finding] = []
    outputs = jax.eval_shape(fn, *args)
    out_sigs = _sig_counts(outputs)
    donated = Counter()
    for argnum in donate_argnums:
        donated.update(_sig_counts(args[argnum]))
    for sig, n_donated in sorted(donated.items()):
        n_out = out_sigs.get(sig, 0)
        if n_out == 0:
            out.append(Finding(
                "D001",
                f"donated buffer {sig[0]} {sig[1]} (x{n_donated}) matches no "
                f"output — the donation is dead and XLA allocates a fresh "
                f"buffer", context))
        elif n_donated > n_out:
            out.append(Finding(
                "D002",
                f"{n_donated} donated buffers of {sig[0]} {sig[1]} but only "
                f"{n_out} matching outputs — {n_donated - n_out} donation(s) "
                f"cannot be absorbed", context))
    return out
