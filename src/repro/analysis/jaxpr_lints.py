"""Jaxpr lints (rules J001-J005): recursive walk over every sub-jaxpr of a
traced serving executable, flagging dtype-contract violations, host
transfers, and executables with large baked-in constants.

The walk is structural — primitives are matched by name, sub-jaxprs are
discovered by duck typing (anything in ``eqn.params`` exposing ``.eqns`` is
an open ``Jaxpr``; anything exposing ``.jaxpr`` is a ``ClosedJaxpr``) — so
it survives jax-internal renames and sees inside ``scan``/``cond``/``pjit``/
``custom_vjp``/``pallas_call`` bodies alike."""
from __future__ import annotations

from typing import Any, Iterator, List, Optional, Tuple

import numpy as np

import jax.numpy as jnp

from repro.analysis.findings import Finding

# baked constants above this many bytes are a recompile/memory hazard
CONST_BYTES_THRESHOLD = 64 * 1024

_LOW_FLOATS = {jnp.dtype(jnp.bfloat16), jnp.dtype(jnp.float16)}
_INT8S = {jnp.dtype(jnp.int8), jnp.dtype(jnp.uint8)}
_WIDE = {jnp.dtype(jnp.float64), jnp.dtype(jnp.complex128)}
_HOST_PRIMS = {"infeed", "outfeed", "device_put", "copy_to_host_async"}


def _dtype_of(var: Any):
    aval = getattr(var, "aval", None)
    dt = getattr(aval, "dtype", None)
    try:
        return jnp.dtype(dt) if dt is not None else None
    except TypeError:  # extended dtypes (typed PRNG keys) are not lintable
        return None


def _src(eqn: Any) -> Tuple[Optional[str], Optional[int]]:
    """Best-effort repo-relative provenance of one equation."""
    try:
        frames = eqn.source_info.traceback.frames
    except Exception:
        return None, None
    repo_frame = user_frame = None
    for fr in frames:
        name = getattr(fr, "file_name", "") or ""
        if "/repro/" in name and "/analysis/" not in name:
            repo_frame = fr  # innermost repo frame wins
            break
        if user_frame is None and "site-packages" not in name \
                and "/jax/" not in name:
            user_frame = fr  # first non-library frame as fallback
    fr = repo_frame or user_frame
    if fr is None:
        return None, None
    return getattr(fr, "file_name", None), getattr(fr, "line_num", None)


def iter_jaxprs(closed: Any) -> Iterator[Tuple[Any, list]]:
    """Yield ``(jaxpr, consts)`` for the closed jaxpr and every nested one."""
    seen: set = set()
    stack: List[Tuple[Any, list]] = []

    def push(obj: Any) -> None:
        if hasattr(obj, "jaxpr") and hasattr(obj, "consts"):  # ClosedJaxpr
            inner = obj.jaxpr
            if id(inner) not in seen:
                seen.add(id(inner))
                stack.append((inner, list(obj.consts)))
        elif hasattr(obj, "eqns"):  # open Jaxpr
            if id(obj) not in seen:
                seen.add(id(obj))
                stack.append((obj, []))

    push(closed)
    while stack:
        jaxpr, consts = stack.pop()
        yield jaxpr, consts
        for eqn in jaxpr.eqns:
            for val in eqn.params.values():
                if isinstance(val, (tuple, list)):
                    for item in val:
                        push(item)
                else:
                    push(val)


def lint_jaxpr(closed: Any, context: str = "") -> List[Finding]:
    """Run rules J001-J005 over a ``ClosedJaxpr`` (from ``jax.make_jaxpr``)."""
    out: List[Finding] = []

    for jaxpr, consts in iter_jaxprs(closed):
        for c in consts:
            size = getattr(c, "size", 0) * getattr(
                getattr(c, "dtype", None), "itemsize", 0)
            if size > CONST_BYTES_THRESHOLD:
                out.append(Finding(
                    "J004",
                    f"executable bakes in a constant of {size} bytes "
                    f"(shape {getattr(c, 'shape', '?')}, "
                    f"dtype {getattr(c, 'dtype', '?')}); pass it as an "
                    f"argument instead", context))
        for eqn in jaxpr.eqns:
            name = eqn.primitive.name
            fpath, fline = _src(eqn)

            if name == "convert_element_type":
                src_dt = _dtype_of(eqn.invars[0])
                dst_dt = _dtype_of(eqn.outvars[0])
                if (src_dt in _INT8S and dst_dt is not None
                        and jnp.issubdtype(dst_dt, jnp.floating)):
                    out.append(Finding(
                        "J001",
                        f"int8 -> {dst_dt} convert: dequantization must go "
                        f"through the int32-accumulate epilogue, not a "
                        f"stray element cast", context, fpath, fline))

            if name in ("dot_general", "conv_general_dilated"):
                lhs, rhs = _dtype_of(eqn.invars[0]), _dtype_of(eqn.invars[1])
                odt = _dtype_of(eqn.outvars[0])
                if lhs in _INT8S or rhs in _INT8S:
                    if odt != jnp.dtype(jnp.int32):
                        out.append(Finding(
                            "J002",
                            f"int8 dot accumulates into {odt}; packed GEMMs "
                            f"must use preferred_element_type=int32",
                            context, fpath, fline))
                elif lhs in _LOW_FLOATS or rhs in _LOW_FLOATS:
                    if odt in _LOW_FLOATS:
                        out.append(Finding(
                            "J002",
                            f"{lhs} x {rhs} dot accumulates into {odt}; use "
                            f"preferred_element_type=f32 and cast the result "
                            f"once", context, fpath, fline))

            if name in _HOST_PRIMS or "callback" in name:
                out.append(Finding(
                    "J003",
                    f"host-transfer primitive '{name}' inside a serving "
                    f"executable", context, fpath, fline))

            for var in eqn.outvars:
                dt = _dtype_of(var)
                if dt in _WIDE:
                    out.append(Finding(
                        "J005",
                        f"{dt} value produced by '{name}' — x64 mode leaking "
                        f"into a serving executable", context, fpath, fline))
    return out


def check_logits_dtype(logits_aval: Any, context: str = "") -> List[Finding]:
    """Rule J006: serving logits must reach the sampler in f32."""
    dt = jnp.dtype(getattr(logits_aval, "dtype", np.float32))
    if dt != jnp.dtype(jnp.float32):
        return [Finding(
            "J006",
            f"model entry returns logits in {dt}; the sampler's f32 upcast "
            f"then operates on quantized values (argmax ties / top-k tails "
            f"resolve wrong) — request f32 from the logits GEMM epilogue",
            context)]
    return []
