"""Finding/report datamodel + the rule catalogue for ``repro.analysis``.

Every rule has a stable id (``J*`` jaxpr lints, ``D*`` donation checks,
``K*`` kernel BlockSpec proofs, ``P*`` paging invariants).  DESIGN.md §8
documents each rule, how to add one, and how to silence one
(``--disable RULE`` on the CLI)."""
from __future__ import annotations

import dataclasses
import json
from typing import Any, Dict, List, Optional

RULES: Dict[str, str] = {
    "J001": "stray dequant: int8 -> float convert outside the designated "
            "int32-accumulate epilogue",
    "J002": "unaccumulated dot: int8 dot without int32 output, or "
            "bf16/f16 dot without f32 accumulation",
    "J003": "host transfer: callback / infeed / outfeed / device_put "
            "primitive inside a serving executable",
    "J004": "baked constant: a closed-over array above the size threshold "
            "is burned into the executable (recompile + memory hazard)",
    "J005": "wide dtype leak: float64/complex128 value inside a serving "
            "executable",
    "J006": "logit round trip: model entry returns logits in a dtype "
            "narrower than f32 (sampler upcasts quantized values)",
    "J007": "sharded-surface hazard: compiled SPMD module all-gathers a "
            "full parameter (sharding constraint undone downstream) or "
            "moves data device-to-host mid-executable",
    "D001": "dead donation: donated input buffer matches no output buffer "
            "(donation silently dropped)",
    "D002": "duplicate donation: more donated buffers of a (shape, dtype) "
            "than outputs that can absorb them",
    "K001": "out-of-bounds block: a BlockSpec index map can return a block "
            "index (or read a scalar table entry) outside its domain",
    "K002": "dead block not elided: DMA count along the innermost grid axis "
            "exceeds the pl.when-live block count (dead blocks must remap "
            "to a live index so the revisit DMA is elided)",
    "K003": "output revisit: output index map varies along a reduction grid "
            "axis (partial accumulator stores)",
    "P001": "paging invariant violation (PagePool/RadixCache structural "
            "check, see paging.check_invariants)",
    "R001": "unreachable resilience branch: a FinishReason the Scheduler "
            "must be able to emit was not produced by the canonical "
            "degraded-mode scenario suite (see runner.check_resilience)",
}


@dataclasses.dataclass(frozen=True)
class Finding:
    rule: str
    message: str
    context: str = ""          # e.g. "config=olmo-1b mode=interpret entry=decode"
    file: Optional[str] = None
    line: Optional[int] = None
    severity: str = "error"

    def where(self) -> str:
        if self.file:
            return f"{self.file}:{self.line or 0}"
        return "<no provenance>"

    def __str__(self) -> str:
        ctx = f" [{self.context}]" if self.context else ""
        return f"{self.rule} {self.where()}{ctx}: {self.message}"

    def to_json(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)


@dataclasses.dataclass
class Report:
    findings: List[Finding] = dataclasses.field(default_factory=list)
    checked: List[str] = dataclasses.field(default_factory=list)
    disabled: List[str] = dataclasses.field(default_factory=list)

    def add(self, finding: Finding) -> None:
        if finding.rule not in self.disabled:
            self.findings.append(finding)

    def extend(self, findings) -> None:
        for f in findings:
            self.add(f)

    def errors(self) -> List[Finding]:
        return [f for f in self.findings if f.severity == "error"]

    def exit_code(self, strict: bool = False) -> int:
        if strict:
            return 1 if self.findings else 0
        return 1 if self.errors() else 0

    def to_json(self) -> Dict[str, Any]:
        return {
            "findings": [f.to_json() for f in self.findings],
            "checked": self.checked,
            "disabled": self.disabled,
            "rules": RULES,
        }

    def dump(self, path: str) -> None:
        with open(path, "w") as fh:
            json.dump(self.to_json(), fh, indent=2, sort_keys=True)
            fh.write("\n")
