"""Compiled-HLO lints (rule J007): sharded-surface hazards.

All-gathers do not exist in jaxprs — the SPMD partitioner materializes
them during compilation — so this checker works on the compiled module's
HLO text instead.  Two hazards are flagged:

* an ``all-gather`` whose result shape matches a parameter leaf (or the
  per-layer slice of a stacked parameter): the placement sharded the
  weight, but a downstream consumer's sharding constraint forces XLA to
  reassemble the full tensor on every device, silently erasing the
  memory/bandwidth win of tensor parallelism;
* a device-to-host transfer (``outfeed``/``infeed`` ops or
  ``SendToHost``-family custom-calls) inside the module — serving
  executables must stay resident on device.

The functions are pure text + shapes, so they are unit-testable without
a multi-device backend; the runner feeds them real compiled modules when
more than one device is present.
"""
from __future__ import annotations

import math
import re
from typing import Any, Iterable, List, Sequence, Set, Tuple

from repro.analysis.findings import Finding

# parameter all-gathers below this many elements are ignored: tiny
# tensors are cheap to regather and their shapes collide with
# activations, producing false positives
GATHER_ELEMS_THRESHOLD = 4096

_HOST_TARGETS = ("SendToHost", "RecvFromHost", "MoveToHost", "MoveToDevice")

# `  %all-gather.3 = f32[2,64,256]{2,1,0} all-gather(...)` -> "2,64,256"
_ALL_GATHER_RE = re.compile(r"=\s*[a-z0-9]+\[([0-9,]*)\]\S*\s+all-gather")
_CUSTOM_CALL_RE = re.compile(r'custom_call_target="([^"]+)"')
_HOST_OP_RE = re.compile(r"=\s*\S+\s+(outfeed|infeed)\(")


def param_gather_shapes(params: Any) -> Set[Tuple[int, ...]]:
    """Shapes whose appearance as an all-gather result means a full
    parameter was reassembled: each leaf's shape, plus the per-layer
    slice of stacked (``[L, ...]``) leaves."""
    import jax

    shapes: Set[Tuple[int, ...]] = set()
    for leaf in jax.tree.leaves(params):
        shp = tuple(getattr(leaf, "shape", ()) or ())
        for cand in (shp,) + ((shp[1:],) if len(shp) >= 3 else ()):
            if cand and math.prod(cand) >= GATHER_ELEMS_THRESHOLD:
                shapes.add(cand)
    return shapes


def lint_hlo(hlo_text: str, shapes: Iterable[Sequence[int]],
             context: str = "") -> List[Finding]:
    """Run rule J007 over one compiled module's HLO text."""
    out: List[Finding] = []
    suspicious = {tuple(s) for s in shapes}
    seen_gathers: Set[Tuple[int, ...]] = set()
    seen_hosts: Set[str] = set()
    for line in hlo_text.splitlines():
        m = _ALL_GATHER_RE.search(line)
        if m:
            dims = tuple(int(d) for d in m.group(1).split(",") if d)
            if dims in suspicious and dims not in seen_gathers:
                seen_gathers.add(dims)
                out.append(Finding(
                    "J007",
                    f"all-gather reassembles a full parameter of shape "
                    f"{dims} — a downstream sharding constraint undoes "
                    f"the weight's placement; shard the consumer or "
                    f"replicate the weight at placement instead", context))
        cm = _CUSTOM_CALL_RE.search(line)
        if cm and any(t in cm.group(1) for t in _HOST_TARGETS) \
                and cm.group(1) not in seen_hosts:
            seen_hosts.add(cm.group(1))
            out.append(Finding(
                "J007",
                f"device-to-host transfer custom-call '{cm.group(1)}' "
                f"inside a compiled serving module", context))
        hm = _HOST_OP_RE.search(line)
        if hm and hm.group(1) not in seen_hosts:
            seen_hosts.add(hm.group(1))
            out.append(Finding(
                "J007",
                f"host-transfer op '{hm.group(1)}' inside a compiled "
                f"serving module", context))
    return out
