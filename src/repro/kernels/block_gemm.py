"""CGRA-style block-wise GEMM as a Pallas TPU kernel (paper claims C1/C2/C4).

The mapping from the paper's 4x4 edge array to the TPU (DESIGN.md §2):

- the PE array's output-stationary sub-matrix blocking -> BlockSpec tiles
  (bm x bn) output blocks accumulated over a bk-strided K grid in a VMEM
  scratch accumulator (f32 / int32);
- the 4x2 MOB LOAD/STORE decoupling -> the pallas_call grid pipeline, which
  double-buffers the HBM->VMEM block copies of A and B ahead of the MXU
  (Pallas emits exactly the decoupled address-generation/DMA the MOBs
  implement in silicon);
- the "packed-data dot product" -> the int8 variant (int8 x int8 -> int32)
  with per-row/per-col rescale fused into the epilogue.

Block shapes come from ``repro.core.cgra.select_block_shapes`` — the same
mapper that places blocks on the 4x4 array, re-parameterized for VMEM/MXU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.cgra import select_block_shapes
from repro.kernels.spec import KernelSpec, OperandSpec, provenance

F32 = jnp.float32


def gemm_spec(M: int, K: int, N: int, *, block_shape=None,
              dtype_bytes: int = 4, int8: bool = False) -> KernelSpec:
    """Grid/BlockSpec contract of ``block_gemm`` / ``block_gemm_int8``."""
    if block_shape is None:
        block_shape = select_block_shapes(M, K, N, dtype_bytes=dtype_bytes)
    bm, bk, bn = block_shape
    Mp, Kp, Np = (-(-M // bm)) * bm, (-(-K // bk)) * bk, (-(-N // bn)) * bn
    nm, nn, nk = Mp // bm, Np // bn, Kp // bk

    def a_map(i, j, k):
        return (i, k)

    def b_map(i, j, k):
        return (k, j)

    def o_map(i, j, k):
        return (i, j)

    operands = [
        OperandSpec("a", (bm, bk), a_map, (nm, nk)),
        OperandSpec("b", (bk, bn), b_map, (nk, nn)),
    ]
    if int8:
        operands += [
            OperandSpec("a_scale", (bm, 1), lambda i, j, k: (i, 0), (nm, 1)),
            OperandSpec("b_scale", (1, bn), lambda i, j, k: (0, j), (1, nn)),
        ]
    operands.append(OperandSpec("o", (bm, bn), o_map, (nm, nn),
                                is_output=True))
    src_file, src_line = provenance(a_map)
    return KernelSpec(
        name="block_gemm_int8" if int8 else "block_gemm",
        grid=(nm, nn, nk),
        scalars=(),
        operands=tuple(operands),
        block_live=None,  # dense GEMM: every block is live
        reduction_axes=(2,),
        src_file=src_file, src_line=src_line,
    )


def _gemm_kernel(a_ref, b_ref, o_ref, acc_ref, *, nk: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(a_ref[...], b_ref[...], preferred_element_type=F32)

    @pl.when(k == nk - 1)
    def _store():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def _pad_to(x, m0, m1):
    p0 = (-x.shape[0]) % m0
    p1 = (-x.shape[1]) % m1
    if p0 or p1:
        x = jnp.pad(x, ((0, p0), (0, p1)))
    return x


def block_gemm(a, b, *, block_shape=None, out_dtype=None, interpret=False):
    """C = A[M,K] @ B[K,N], output-stationary block accumulation.

    Arbitrary shapes are padded up to the block grid (the CGRA handles
    ragged edges the same way: partial blocks run at lower utilization).
    """
    out_dtype = out_dtype or a.dtype
    M, K = a.shape
    K2, N = b.shape
    assert K == K2, (a.shape, b.shape)
    spec = gemm_spec(M, K, N, block_shape=block_shape,
                     dtype_bytes=a.dtype.itemsize)
    bm, bk, bn = (spec.operands[0].block_shape[0],
                  spec.operands[0].block_shape[1],
                  spec.operands[1].block_shape[1])
    ap = _pad_to(a, bm, bk)
    bp = _pad_to(b, bk, bn)
    Mp, Np = spec.grid[0] * bm, spec.grid[1] * bn
    nk = spec.grid[2]
    out = pl.pallas_call(
        functools.partial(_gemm_kernel, nk=nk),
        grid=spec.grid,
        in_specs=[pl.BlockSpec(op.block_shape, op.index_map)
                  for op in spec.inputs],
        out_specs=pl.BlockSpec(spec.outputs[0].block_shape,
                               spec.outputs[0].index_map),
        out_shape=jax.ShapeDtypeStruct((Mp, Np), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), F32)],
        interpret=interpret,
    )(ap, bp)
    return out[:M, :N]


def _gemm_int8_kernel(a_ref, b_ref, sa_ref, sb_ref, o_ref, acc_ref, *, nk: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot(
        a_ref[...], b_ref[...],
        preferred_element_type=jnp.int32,
    )

    @pl.when(k == nk - 1)
    def _store():  # fused dequant epilogue: per-row x per-col scales
        o_ref[...] = (acc_ref[...].astype(F32) * sa_ref[...] * sb_ref[...]
                      ).astype(o_ref.dtype)


def block_gemm_int8(a_q, b_q, a_scale, b_scale, *, block_shape=None,
                    out_dtype=F32, interpret=False):
    """Packed-data GEMM: int8 operands, int32 accumulate, fused rescale.

    a_q: [M,K] int8; b_q: [K,N] int8; a_scale: [M,1] f32; b_scale: [1,N] f32.
    """
    M, K = a_q.shape
    N = b_q.shape[1]
    spec = gemm_spec(M, K, N, block_shape=block_shape, dtype_bytes=1,
                     int8=True)
    bm, bk, bn = (spec.operands[0].block_shape[0],
                  spec.operands[0].block_shape[1],
                  spec.operands[1].block_shape[1])
    ap = _pad_to(a_q, bm, bk)
    bp = _pad_to(b_q, bk, bn)
    sa = _pad_to(a_scale.astype(F32), bm, 1)
    sb = _pad_to(b_scale.astype(F32), 1, bn)
    Mp, Np = spec.grid[0] * bm, spec.grid[1] * bn
    nk = spec.grid[2]
    out = pl.pallas_call(
        functools.partial(_gemm_int8_kernel, nk=nk),
        grid=spec.grid,
        in_specs=[pl.BlockSpec(op.block_shape, op.index_map)
                  for op in spec.inputs],
        out_specs=pl.BlockSpec(spec.outputs[0].block_shape,
                               spec.outputs[0].index_map),
        out_shape=jax.ShapeDtypeStruct((Mp, Np), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.int32)],
        interpret=interpret,
    )(ap, bp, sa, sb)
    return out[:M, :N]
