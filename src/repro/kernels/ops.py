"""jit'd public wrappers around the Pallas kernels, with kernel_mode dispatch
(reference | interpret | pallas) and a custom VJP for the block GEMM so the
kernel path is trainable."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.cache import CacheLayout
from repro.kernels import ref
from repro.kernels.block_gemm import block_gemm, block_gemm_int8
from repro.kernels.decode_attention import flash_decode
from repro.kernels.flash_attention import flash_attention


@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3))
def cgra_matmul(a, b, mode: str = "reference", out_dtype=None):
    """C = A @ B through the CGRA block-GEMM path.

    ``out_dtype`` requests the epilogue's store dtype: the f32 accumulator
    is cast exactly once, so callers that need full-precision outputs (the
    logits head) avoid an f32 -> compute-dtype -> f32 round trip."""
    if mode == "reference":
        return ref.block_gemm_ref(a, b, out_dtype=out_dtype)
    return block_gemm(a, b, out_dtype=out_dtype,
                      interpret=(mode == "interpret"))


def _mm_fwd(a, b, mode, out_dtype):
    return cgra_matmul(a, b, mode, out_dtype), (a, b)


def _mm_bwd(mode, out_dtype, res, g):
    a, b = res
    ga = cgra_matmul(g.astype(b.dtype), b.T, mode).astype(a.dtype)
    gb = cgra_matmul(a.T, g.astype(a.dtype), mode).astype(b.dtype)
    return ga, gb


cgra_matmul.defvjp(_mm_fwd, _mm_bwd)


def cgra_matmul_int8(a_q, b_q, a_scale, b_scale, mode: str = "reference",
                     out_dtype=jnp.float32):
    """Packed int8 GEMM with fused per-row/per-col dequant (inference)."""
    if mode == "reference":
        return ref.block_gemm_int8_ref(a_q, b_q, a_scale, b_scale, out_dtype)
    return block_gemm_int8(a_q, b_q, a_scale, b_scale,
                           interpret=(mode == "interpret"), out_dtype=out_dtype)


def attention(q, k, v, *, causal=True, window=0, softcap=0.0,
              pages=None, q_start=None, k_len=None,
              mode: str = "reference", bq=128, bk=128):
    """q: [B,H,Sq,d]; k/v: [B,K,Sk,d] (GQA: H % K == 0).  Ragged Sq/Sk ok;
    causal masking aligns the last query with the last key (``Sq < Sk`` is
    the suffix-prefill pattern over a cached prefix).

    ``pages`` ([B, npp] int32) switches to the chunked-prefill *paged past*
    layout: k/v become page pools ``[n_pages, page_size, K, d]`` and
    ``q_start``/``k_len`` [B] place the query chunk at absolute positions
    ``q_start + i`` attending over logical rows ``[0, k_len)``."""
    if pages is not None:
        if mode == "reference":
            return ref.flash_attention_paged_ref(q, k, v, pages, q_start,
                                                 k_len, window=window,
                                                 softcap=softcap)
        return flash_attention(q, k, v, pages=pages, q_start=q_start,
                               k_len=k_len, window=window, softcap=softcap,
                               bq=bq, interpret=(mode == "interpret"))
    if mode == "reference":
        G = q.shape[1] // k.shape[1]
        kb = jnp.repeat(k, G, axis=1)
        vb = jnp.repeat(v, G, axis=1)
        return ref.flash_attention_ref(q, kb, vb, causal=causal, window=window,
                                       softcap=softcap)
    return flash_attention(q, k, v, causal=causal, window=window,
                           softcap=softcap, bq=bq, bk=bk,
                           interpret=(mode == "interpret"))


def attend_decode(q, k, v, pos, start=None, *,
                  layout: str | CacheLayout = CacheLayout.LINEAR,
                  softcap=0.0, scale=None, dv=None, pages=None,
                  mode: str = "reference", bk=128):
    """Batched single-token decode over a slot-indexed KV cache.

    Cache-native layout (no hot-path transposes): q: [B,H,dq];
    k: [B,S,K,dq]; v: [B,S,K,>=dv] -> [B,H,dv].  ``pos``/``start`` are the
    per-slot [B] validity bounds; ``layout`` is the :class:`CacheLayout`
    (LINEAR global / RING sliding-window / PAGED block-table).  ``dv``
    narrows the value read to the first dv columns — MLA latent decode
    passes its concatenated ``[latent | k_rope]`` cache as both k and v.
    ``pages`` ([B, npp] int32) switches k/v to page pools
    ``[n_pages, page_size, K, d]`` indirected through the table.
    """
    layout = str(layout)
    if mode == "reference":
        return ref.flash_decode_ref(q, k, v, pos, start, layout=layout,
                                    softcap=softcap, scale=scale, dv=dv,
                                    pages=pages)
    return flash_decode(q, k, v, pos, start, layout=layout, softcap=softcap,
                        scale=scale, dv=dv, bk=bk, pages=pages,
                        interpret=(mode == "interpret"))
