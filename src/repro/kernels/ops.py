"""jit'd public wrappers around the Pallas kernels, with kernel_mode dispatch
(reference | interpret | pallas) and a custom VJP for the block GEMM so the
kernel path is trainable."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.block_gemm import block_gemm, block_gemm_int8
from repro.kernels.flash_attention import flash_attention


@functools.partial(jax.custom_vjp, nondiff_argnums=(2,))
def cgra_matmul(a, b, mode: str = "reference"):
    """C = A @ B through the CGRA block-GEMM path."""
    if mode == "reference":
        return ref.block_gemm_ref(a, b)
    return block_gemm(a, b, interpret=(mode == "interpret"))


def _mm_fwd(a, b, mode):
    return cgra_matmul(a, b, mode), (a, b)


def _mm_bwd(mode, res, g):
    a, b = res
    ga = cgra_matmul(g.astype(b.dtype), b.T, mode).astype(a.dtype)
    gb = cgra_matmul(a.T, g.astype(a.dtype), mode).astype(b.dtype)
    return ga, gb


cgra_matmul.defvjp(_mm_fwd, _mm_bwd)


def cgra_matmul_int8(a_q, b_q, a_scale, b_scale, mode: str = "reference",
                     out_dtype=jnp.float32):
    """Packed int8 GEMM with fused per-row/per-col dequant (inference)."""
    if mode == "reference":
        return ref.block_gemm_int8_ref(a_q, b_q, a_scale, b_scale, out_dtype)
    return block_gemm_int8(a_q, b_q, a_scale, b_scale,
                           interpret=(mode == "interpret"), out_dtype=out_dtype)


def attention(q, k, v, *, causal=True, window=0, softcap=0.0,
              mode: str = "reference", bq=128, bk=128):
    """q: [B,H,Sq,d]; k/v: [B,K,Sk,d] (GQA: H % K == 0).  Ragged Sq/Sk ok."""
    if mode == "reference":
        G = q.shape[1] // k.shape[1]
        kb = jnp.repeat(k, G, axis=1)
        vb = jnp.repeat(v, G, axis=1)
        return ref.flash_attention_ref(q, kb, vb, causal=causal, window=window,
                                       softcap=softcap)
    return flash_attention(q, k, v, causal=causal, window=window,
                           softcap=softcap, bq=bq, bk=bk,
                           interpret=(mode == "interpret"))
