"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

F32 = jnp.float32


def block_gemm_ref(a, b, out_dtype=None):
    """C = A @ B with f32 accumulation."""
    out_dtype = out_dtype or a.dtype
    return jnp.matmul(a, b, preferred_element_type=F32).astype(out_dtype)


def block_gemm_int8_ref(a_q, b_q, a_scale, b_scale, out_dtype=F32):
    """int8 x int8 -> int32 accumulate, rescale per-row(a) x per-col(b).

    a_q: [M,K] int8, b_q: [K,N] int8, a_scale: [M,1] f32, b_scale: [1,N] f32.
    """
    acc = jnp.matmul(a_q.astype(jnp.int32), b_q.astype(jnp.int32))
    return (acc.astype(F32) * a_scale * b_scale).astype(out_dtype)


def flash_attention_ref(q, k, v, *, causal=True, window=0, scale=None,
                        softcap=0.0):
    """q: [B,H,Sq,d], k/v: [B,H,Sk,d] (kv heads already broadcast).
    Causal masking aligns the last query with the last key (``Sq < Sk`` is
    the suffix-prefill pattern: queries continue a cached prefix).
    Fully-masked rows return zeros (matching the Pallas kernel)."""
    B, H, Sq, d = q.shape
    Sk = k.shape[2]
    scale = scale if scale is not None else d ** -0.5
    s = jnp.einsum("bhqd,bhkd->bhqk", q, k, preferred_element_type=F32) * scale
    if softcap:
        s = jnp.tanh(s / softcap) * softcap
    qpos = jnp.arange(Sq)[:, None]
    kpos = jnp.arange(Sk)[None, :]
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask &= kpos <= qpos + (Sk - Sq)  # align last query with last key
    if window:
        mask &= kpos > qpos + (Sk - Sq) - window
    mask = jnp.broadcast_to(mask[None], (B, Sq, Sk))
    s = jnp.where(mask[:, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(mask[:, None], p, 0.0)  # all-masked row -> zeros, not 1/Sk
    # f32 accumulation like the kernel's VMEM accumulator, one final cast
    return jnp.einsum("bhqk,bhkd->bhqd", p.astype(v.dtype), v,
                      preferred_element_type=F32).astype(v.dtype)


def flash_attention_paged_ref(q, k, v, pages, q_start, k_len, *, window=0,
                              scale=None, softcap=0.0):
    """Oracle for ``flash_attention(pages=...)``: a query *chunk* attending
    over a paged past (chunked prefill).  q: [B,H,C,d]; k/v: page pools
    [n_pages, page_size, K, d] (H % K == 0, GQA); pages: [B, npp] int32 page
    tables; q_start/k_len: [B] int32 — query row ``i`` of slot ``b`` sits at
    logical position ``q_start[b] + i`` and attends causally over logical
    rows ``[0, k_len[b])`` (which include the chunk's own freshly-written
    keys).  The oracle gathers each slot's pages into a dense
    [B, npp * page_size, K, d] cache and applies the absolute-position
    causal/window mask — the page table is pure indirection.  Query rows
    past the chunk's valid length are the caller's padding; their output is
    unspecified (the engine slices them off)."""
    pages = jnp.asarray(pages, jnp.int32)
    B, H, C, d = q.shape
    ps, K = k.shape[1], k.shape[2]
    npp = pages.shape[1]
    G = H // K
    S = npp * ps
    scale = scale if scale is not None else d ** -0.5
    q_start = jnp.broadcast_to(jnp.asarray(q_start, jnp.int32), (B,))
    k_len = jnp.broadcast_to(jnp.asarray(k_len, jnp.int32), (B,))
    shared = v is k
    kd = k[pages].reshape(B, S, K, k.shape[-1])
    kb = jnp.repeat(kd, G, axis=2)  # [B,S,H,d]
    if shared:
        vb = kb
    else:
        vd = v[pages].reshape(B, S, K, v.shape[-1])
        vb = jnp.repeat(vd, G, axis=2)
    s = jnp.einsum("bhqd,bshd->bhqs", q, kb,
                   preferred_element_type=F32) * scale
    if softcap:
        s = jnp.tanh(s / softcap) * softcap
    qpos = q_start[:, None] + jnp.arange(C, dtype=jnp.int32)[None]  # [B,C]
    kpos = jnp.arange(S, dtype=jnp.int32)
    mask = (kpos[None, None, :] < k_len[:, None, None]) & \
           (kpos[None, None, :] <= qpos[:, :, None])
    if window:
        mask &= kpos[None, None, :] > qpos[:, :, None] - window
    s = jnp.where(mask[:, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(mask[:, None], p, 0.0)  # all-masked row -> zeros
    return jnp.einsum("bhqs,bshd->bhqd", p.astype(vb.dtype), vb,
                      preferred_element_type=F32).astype(vb.dtype)


def flash_decode_ref(q, k, v, pos, start=None, *, layout="linear",
                     softcap=0.0, scale=None, dv=None, pages=None):
    """Oracle for ``flash_decode``: batched single-token decode over a
    slot-indexed cache in its native layout.  q: [B,H,dq]; k: [B,S,K,dq];
    v: [B,S,K,>=dv]; pos/start: [B] int32 (broadcastable).  ``layout``:
    "linear" (rows ``[start, pos]`` live) or "ring" (entry j holds absolute
    row ``pos - ((pos - j) mod S)``; live iff that row is
    ``>= max(start, 0)``).  ``dv`` reads only the first dv value columns
    (MLA passes one concatenated cache as both k and v).  All-invalid slots
    return zeros.

    Paged path: ``pages`` [B, npp] int32 page tables over pools k/v of shape
    [n_pages, page_size, K, d]; logical row ``r`` of slot ``b`` lives at
    ``(pages[b, r // page_size], r % page_size)``.  The oracle gathers each
    slot's pages into a dense [B, npp * page_size, K, d] cache and falls
    through to the linear rule — the page table is pure indirection, the
    validity semantics are unchanged."""
    if pages is not None:
        assert layout in ("linear", "paged"), layout
        pages = jnp.asarray(pages, jnp.int32)
        B_, npp = pages.shape
        ps = k.shape[1]
        shared = v is k
        k = k[pages].reshape(B_, npp * ps, *k.shape[2:])
        v = k if shared else v[pages].reshape(B_, npp * ps, *v.shape[2:])
        layout = "linear"
    B, H, dq = q.shape
    S, K = k.shape[1], k.shape[2]
    G = H // K
    scale = scale if scale is not None else dq ** -0.5
    pos = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (B,))
    start = (jnp.zeros((B,), jnp.int32) if start is None
             else jnp.broadcast_to(jnp.asarray(start, jnp.int32), (B,)))
    if dv is not None:
        v = v[..., :dv]
    qg = q.reshape(B, K, G, dq)
    s = jnp.einsum("bkgd,bskd->bkgs", qg, k, preferred_element_type=F32) * scale
    if softcap:
        s = jnp.tanh(s / softcap) * softcap
    j = jnp.arange(S)[None, :]
    if layout == "ring":
        a = pos[:, None] - jnp.mod(pos[:, None] - j, S)
        valid = (a >= 0) & (a >= start[:, None])
    else:
        valid = (j >= start[:, None]) & (j <= pos[:, None])
    vm = valid[:, None, None, :]
    s = jnp.where(vm, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(vm, p, 0.0)  # all-invalid slot -> zeros
    o = jnp.einsum("bkgs,bskd->bkgd", p.astype(v.dtype), v,
                   preferred_element_type=F32).astype(v.dtype)
    return o.reshape(B, H, v.shape[-1])
