"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

F32 = jnp.float32


def block_gemm_ref(a, b, out_dtype=None):
    """C = A @ B with f32 accumulation."""
    out_dtype = out_dtype or a.dtype
    return jnp.matmul(a, b, preferred_element_type=F32).astype(out_dtype)


def block_gemm_int8_ref(a_q, b_q, a_scale, b_scale, out_dtype=F32):
    """int8 x int8 -> int32 accumulate, rescale per-row(a) x per-col(b).

    a_q: [M,K] int8, b_q: [K,N] int8, a_scale: [M,1] f32, b_scale: [1,N] f32.
    """
    acc = jnp.matmul(a_q.astype(jnp.int32), b_q.astype(jnp.int32))
    return (acc.astype(F32) * a_scale * b_scale).astype(out_dtype)


def flash_attention_ref(q, k, v, *, causal=True, window=0, scale=None,
                        softcap=0.0):
    """q: [B,H,Sq,d], k/v: [B,H,Sk,d] (kv heads already broadcast).
    Fully-masked rows return zeros (matching the Pallas kernel)."""
    B, H, Sq, d = q.shape
    Sk = k.shape[2]
    scale = scale if scale is not None else d ** -0.5
    s = jnp.einsum("bhqd,bhkd->bhqk", q, k, preferred_element_type=F32) * scale
    if softcap:
        s = jnp.tanh(s / softcap) * softcap
    qpos = jnp.arange(Sq)[:, None]
    kpos = jnp.arange(Sk)[None, :]
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask &= kpos <= qpos + (Sk - Sq)  # align last query with last key
    if window:
        mask &= kpos > qpos + (Sk - Sq) - window
    s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(mask[None, None], p, 0.0)  # all-masked row -> zeros, not 1/Sk
    return jnp.einsum("bhqk,bhkd->bhqd", p.astype(v.dtype), v)
