"""Introspectable kernel contracts.

Every Pallas kernel in this package builds its grid / BlockSpec geometry
through a :class:`KernelSpec` so that the same index maps and block-liveness
predicates that drive ``pl.pallas_call`` can be enumerated and *proven*
in-bounds by ``repro.analysis`` without duplicating any index arithmetic.

A ``KernelSpec`` is pure data plus plain callables: the index maps take the
grid indices followed by one array per scalar-prefetch operand (mirroring
Pallas' calling convention for ``PrefetchScalarGridSpec`` index maps), and
``block_live`` — when present — is the same predicate the kernel body feeds
to ``pl.when`` to skip dead blocks.  ``ScalarSpec`` declares the worst-case
domain of each scalar operand (page-table entries, ``pos``/``start``/
``k_len`` extremes) that the bounds prover enumerates.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ScalarSpec:
    """Worst-case domain of one scalar-prefetch operand.

    ``lo``/``hi`` are *inclusive* elementwise bounds.  They are deliberately
    hostile: they cover every value the public kernel API accepts, not just
    what the engine produces (e.g. ``pos == max_len`` for frozen slots,
    ``k_len == 0`` for an empty chunk).
    """

    name: str
    shape: Tuple[int, ...]
    lo: int
    hi: int


@dataclasses.dataclass(frozen=True)
class OperandSpec:
    """One blocked operand (input or output) of a kernel.

    ``grid_blocks`` is the number of valid blocks per array dimension, i.e.
    ``padded_dim_size // block_shape[d]`` — the index map must return a block
    index in ``[0, grid_blocks[d])`` for every dimension ``d``.
    """

    name: str
    block_shape: Tuple[int, ...]
    index_map: Callable[..., Tuple[Any, ...]]
    grid_blocks: Tuple[int, ...]
    is_output: bool = False


@dataclasses.dataclass(frozen=True)
class KernelSpec:
    """Grid + BlockSpec contract of one Pallas kernel instantiation.

    ``block_live(*grid_ids, *scalar_arrays) -> bool`` must match the
    ``pl.when`` predicate used inside the kernel body; ``None`` means the
    kernel visits every block.  ``reduction_axes`` are the grid axes along
    which output blocks are revisited (accumulated in VMEM) — the output
    index map must be invariant along them.
    """

    name: str
    grid: Tuple[int, ...]
    scalars: Tuple[ScalarSpec, ...]
    operands: Tuple[OperandSpec, ...]
    block_live: Optional[Callable[..., Any]] = None
    reduction_axes: Tuple[int, ...] = ()
    src_file: str = ""
    src_line: int = 0

    @property
    def outputs(self) -> Tuple[OperandSpec, ...]:
        return tuple(op for op in self.operands if op.is_output)

    @property
    def inputs(self) -> Tuple[OperandSpec, ...]:
        return tuple(op for op in self.operands if not op.is_output)


def provenance(fn: Callable[..., Any]) -> Tuple[str, int]:
    """(file, line) of a callable, for finding reports."""
    code = getattr(fn, "__code__", None)
    if code is None:  # functools.partial etc.
        inner = getattr(fn, "func", None)
        code = getattr(inner, "__code__", None)
    if code is None:
        return "<unknown>", 0
    return code.co_filename, code.co_firstlineno
