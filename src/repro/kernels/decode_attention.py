"""Batched single-token flash-decode as a Pallas TPU kernel.

Decode is the serving hot path the paper's MOB/PE dataflow is actually about:
one query row per sequence against a long KV cache, so the op is purely
memory-bound and the win is reading *only the live cache region* exactly
once.  This kernel is the decode-side counterpart of ``flash_attention``:

- the KV cache is streamed in ``bk``-row blocks (the MOB prefetch pipeline),
  with a running max/denominator online-softmax accumulator in VMEM so the
  [H, S] score matrix never materializes (C4 data reuse);
- per-slot ``pos`` (tokens decoded so far) and ``start`` (validity lower
  bound: 0, or ``pos - window + 1`` for sliding-window layers on a linear
  cache) scalars ride in via scalar prefetch and drive in-kernel validity,
  so dead cache rows — the slot's unwritten tail and anything below
  ``start`` — never receive weight;
- for the linear (global-attention) layout, k-blocks entirely outside the
  live ``[start, pos]`` range are skipped outright: their compute is gated
  by ``pl.when`` and their BlockSpec index remaps to a live block (repeat
  visits elide the HBM->VMEM copy), so both score work and cache traffic
  are bounded by the live length, not ``max_len``;
- the ring (sliding-window) layout recovers each entry's absolute row from
  ``pos`` in-kernel (entry ``j`` holds row ``pos - ((pos - j) mod S)``), so
  wrapped caches need no reordering in HBM;
- GQA folds the G query heads that share a kv head into the sublane axis
  (one [G, d] x [d, bk] MXU call per block — no KV broadcast), and the
  qk/v head dims may differ (MLA's latent-space decode: qk = kvr + rope,
  v = kvr).

A fully-invalid slot (``start > pos``, e.g. a drained engine slot) returns
exact zeros, mirroring the masked-row contract of ``flash_attention``.

Paged mode (``pages=``): k/v are *page pools* ``[n_pages, page_size, K, d]``
shared by every sequence, and a scalar-prefetched per-sequence page table
``[B, npp]`` rides alongside ``pos``/``start``.  One k-block is one page and
the BlockSpec index map follows the table — logical block ``ik`` of slot
``b`` streams page ``pages[b, ik]`` from the pool — so the kernel body is
bit-for-bit the linear layout over logical rows; the indirection lives
entirely in the index map, and the same dead-block clipping bounds HBM
traffic by the live length.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import round_up
from repro.kernels.spec import KernelSpec, OperandSpec, ScalarSpec, provenance

F32 = jnp.float32
NEG = -1e30


def _fd_block_live(ik, p_b, s_b, *, bk: int, layout: str):
    """Liveness of k-block ``ik`` for a slot with bounds ``[s_b, p_b]``.

    Linear layout: the block overlaps the live row range.  Ring layout: live
    entries can sit anywhere in the buffer, so every block of a non-drained
    slot is live (there is no dead-block DMA-elision contract for rings).
    Shared between the kernel body (``pl.when``) and the spec builders."""
    if layout == "linear":
        return (ik * bk <= p_b) & (ik * bk + bk > s_b)
    return p_b >= s_b


def fd_dense_spec(B: int, H: int, K: int, S: int, dq: int, dv: int, *,
                  layout: str = "linear", bk: int = 128) -> KernelSpec:
    """Grid/BlockSpec contract of the dense ``flash_decode`` kernel.

    Scalar domains are hostile: ``pos`` reaches ``S`` (a frozen slot whose
    last token filled the cache keeps ``pos == S``) and ``start`` may exceed
    ``pos`` (a drained slot).  The ring layout has ``block_live=None``: its
    slot-level ``pl.when`` gate skips compute but every block's DMA still
    runs, since any entry of a wrapped buffer may be live."""
    G = H // K
    Gp = round_up(G, 8)
    bk_ = min(bk, S)
    if S % bk_:
        divs = [d for d in range(32, bk_ + 1) if S % d == 0 and d % 8 == 0]
        if divs:
            bk_ = max(divs)
    Sp = round_up(S, bk_)
    nk = Sp // bk_

    def q_map(b, kh, ik, *_):
        return (b, kh, 0, 0)

    def kv_map(b, kh, ik, pos_ref, start_ref):
        if layout == "linear":
            # dead k-blocks (outside [start, pos]) revisit a live block
            # index instead: the grid pipeline elides the repeated DMA, so
            # HBM traffic — the cost that dominates decode — is bounded by
            # the live length, not the cache capacity.  The kernel skips
            # their compute (block_live) so the remapped data is never read.
            lo = jnp.minimum(start_ref[b] // bk_, nk - 1)
            hi = jnp.minimum(pos_ref[b] // bk_, nk - 1)  # pos >= S: dropped
            ik = jnp.clip(ik, lo, hi)
        return (b, ik, kh, 0)

    def block_live(b, kh, ik, pos, start):
        return _fd_block_live(ik, pos[b], start[b], bk=bk_, layout=layout)

    src_file, src_line = provenance(kv_map)
    return KernelSpec(
        name=f"flash_decode_{layout}",
        grid=(B, K, nk),
        scalars=(
            ScalarSpec("pos", (B,), 0, S),
            ScalarSpec("start", (B,), 0, S),
        ),
        operands=(
            OperandSpec("q", (1, 1, Gp, dq), q_map, (B, K, 1, 1)),
            OperandSpec("k", (1, bk_, 1, dq), kv_map, (B, nk, K, 1)),
            OperandSpec("v", (1, bk_, 1, dv), kv_map, (B, nk, K, 1)),
            OperandSpec("o", (1, 1, Gp, dv), q_map, (B, K, 1, 1),
                        is_output=True),
        ),
        block_live=block_live if layout == "linear" else None,
        reduction_axes=(2,),
        src_file=src_file, src_line=src_line,
    )


def fd_paged_spec(B: int, H: int, K: int, dq: int, dv: int, ps: int,
                  npp: int, n_pages: int) -> KernelSpec:
    """Grid/BlockSpec contract of the paged ``flash_decode`` kernel."""
    G = H // K
    Gp = round_up(G, 8)
    S = npp * ps

    def q_map(b, kh, ik, *_):
        return (b, kh, 0, 0)

    def kv_map(b, kh, ik, pos_ref, start_ref, pages_ref):
        # dead logical blocks revisit a live page (repeat index -> the DMA
        # is elided), exactly like the dense linear layout's clipping
        lo = jnp.minimum(start_ref[b] // ps, npp - 1)
        hi = jnp.minimum(pos_ref[b] // ps, npp - 1)
        ik = jnp.clip(ik, lo, hi)
        return (pages_ref[b, ik], 0, kh, 0)

    def block_live(b, kh, ik, pos, start, pages):
        return _fd_block_live(ik, pos[b], start[b], bk=ps, layout="linear")

    src_file, src_line = provenance(kv_map)
    return KernelSpec(
        name="flash_decode_paged",
        grid=(B, K, npp),
        scalars=(
            ScalarSpec("pos", (B,), 0, S),
            ScalarSpec("start", (B,), 0, S),
            ScalarSpec("pages", (B, npp), 0, n_pages - 1),
        ),
        operands=(
            OperandSpec("q", (1, 1, Gp, dq), q_map, (B, K, 1, 1)),
            OperandSpec("k", (1, ps, 1, dq), kv_map, (n_pages, 1, K, 1)),
            OperandSpec("v", (1, ps, 1, dv), kv_map, (n_pages, 1, K, 1)),
            OperandSpec("o", (1, 1, Gp, dv), q_map, (B, K, 1, 1),
                        is_output=True),
        ),
        block_live=block_live,
        reduction_axes=(2,),
        src_file=src_file, src_line=src_line,
    )


def _fd_kernel(pos_ref, start_ref, q_ref, k_ref, v_ref, o_ref,
               m_ref, l_ref, acc_ref, *, nk: int, bk: int, S: int,
               layout: str, softcap: float, scale: float):
    """One (batch-slot, kv-head, k-block) grid step.

    ``S`` is the unpadded cache capacity; rows ``>= S`` are grid padding.
    ``pos_ref``/``start_ref`` are the scalar-prefetched per-slot validity
    bounds (cache row of the current token / first live row).
    """
    b = pl.program_id(0)
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    p_b = pos_ref[b]
    s_b = start_ref[b]

    # linear: live rows are exactly [start, pos] — skip blocks fully outside
    # so the streamed score work is bounded by the live length, not S.
    # ring: live entries can sit anywhere, gate only on a drained slot.
    block_live = _fd_block_live(ik, p_b, s_b, bk=bk, layout=layout)

    @pl.when(block_live)
    def _block():
        q = q_ref[0, 0]       # [Gp, dq]
        k = k_ref[0, :, 0]    # [bk, dq]  (cache-native [B, S, K, d] layout)
        v = v_ref[0, :, 0]    # [bk, dv]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=F32) * scale
        if softcap:
            s = jnp.tanh(s / softcap) * softcap
        j = ik * bk + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        if layout == "ring":
            # entry j holds absolute row pos - ((pos - j) mod S): the last S
            # writes, with entry (pos mod S) freshly holding row pos
            a = p_b - jnp.mod(p_b - j, S)
            valid = (a >= 0) & (a >= s_b)
        else:
            valid = (j >= s_b) & (j <= p_b)
        valid &= j < S  # grid padding: ragged S rounded up to bk
        s = jnp.where(valid, s, NEG)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(-1, keepdims=True))
        p = jnp.exp(s - m_new)
        # rows with no valid key keep m_new == NEG, where the update above
        # degenerates to exp(0) == 1 per entry; zero them so l stays 0 and
        # the store emits exact zeros (empty-slot contract).
        p = jnp.where(m_new > NEG * 0.5, p, 0.0)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + p.sum(-1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot(
            p.astype(v.dtype), v, preferred_element_type=F32)
        m_ref[...] = m_new

    @pl.when(ik == nk - 1)
    def _store():
        o_ref[0, 0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)
                       ).astype(o_ref.dtype)


def _fd_kernel_paged(pos_ref, start_ref, pages_ref, *args, **kw):
    """Paged entry: the page table is consumed by the BlockSpec index map
    only — the kernel body works in logical rows and never sees it."""
    del pages_ref
    _fd_kernel(pos_ref, start_ref, *args, **kw)


def _flash_decode_paged(q, k, v, pos, start, pages, *, softcap: float,
                        scale, dv: int | None, interpret: bool):
    """q: [B, H, dq]; k/v: page pools [P, ps, K, d]; pages: [B, npp] int32
    -> [B, H, dv].  Logical row ``r`` of slot ``b`` lives at pool row
    ``(pages[b, r // ps], r % ps)``; validity is the linear rule over
    logical rows ``[start, pos]``.  ``ps`` must be a multiple of 8
    (sublane alignment — enforced by ``EngineConfig``)."""
    B, H, dq = q.shape
    ps, K = k.shape[1], k.shape[2]
    npp = pages.shape[1]
    dv = dv or v.shape[-1]
    G = H // K
    scale = scale if scale is not None else dq ** -0.5
    pos = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (B,))
    start = (jnp.zeros((B,), jnp.int32) if start is None
             else jnp.broadcast_to(jnp.asarray(start, jnp.int32), (B,)))
    pages = jnp.asarray(pages, jnp.int32)
    shared = v is k  # MLA dual-operand form
    if k.dtype != q.dtype:
        k = k.astype(q.dtype)
    if shared:
        v = k
    elif v.dtype != q.dtype:
        v = v.astype(q.dtype)

    Gp = round_up(G, 8)
    qg = q.reshape(B, K, G, dq)
    if Gp != G:
        qg = jnp.pad(qg, ((0, 0), (0, 0), (0, Gp - G), (0, 0)))
    spec = fd_paged_spec(B, H, K, dq, dv, ps, npp, k.shape[0])

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,  # pos, start, pages
        grid=spec.grid,
        in_specs=[pl.BlockSpec(op.block_shape, op.index_map)
                  for op in spec.inputs],
        out_specs=pl.BlockSpec(spec.outputs[0].block_shape,
                               spec.outputs[0].index_map),
        scratch_shapes=[
            pltpu.VMEM((Gp, 1), F32),
            pltpu.VMEM((Gp, 1), F32),
            pltpu.VMEM((Gp, dv), F32),
        ],
    )
    out = pl.pallas_call(
        functools.partial(_fd_kernel_paged, nk=npp, bk=ps, S=npp * ps,
                          layout="linear", softcap=softcap, scale=scale),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, K, Gp, dv), q.dtype),
        interpret=interpret,
    )(pos, start, pages, qg, k, v)
    return out[:, :, :G].reshape(B, H, dv)


def flash_decode(q, k, v, pos, start=None, *, layout: str = "linear",
                 softcap: float = 0.0, scale=None, bk: int = 128,
                 dv: int | None = None, pages=None, interpret: bool = False):
    """q: [B, H, dq]; k: [B, S, K, dq]; v: [B, S, K, >=dv] -> [B, H, dv].

    k/v arrive in the engine's *native* slot-cache layout ``[B, S, K, d]``
    (seq-major) — the kernel blocks the S axis directly, so the hot path
    never transposes or copies the cache.  ``pos``/``start``: [B] int32
    per-slot validity bounds (broadcastable scalars accepted; ``start=None``
    means every row from 0 is live).  ``layout`` selects the validity rule:
    ``"linear"`` (global attention, rows ``[start, pos]`` live) or ``"ring"``
    (sliding window of size S, entry ``pos % S`` holding the current token).
    H % K == 0 (GQA).  ``dv`` narrows the value read to the first ``dv``
    columns of ``v`` via the BlockSpec (no slicing copy): MLA passes its
    concatenated ``[latent | k_rope]`` cache as BOTH k and v, with the
    latent (the value) being the first ``kv_lora_rank`` columns.

    ``pages`` switches to the paged cache: k/v become page pools
    ``[n_pages, page_size, K, d]`` and ``pages`` the [B, npp] page table
    (see :func:`_flash_decode_paged`); ``layout`` must be linear/paged —
    sliding windows under paging express validity through ``start``
    (``max(0, pos - window + 1)``), not a ring.
    """
    if pages is not None:
        assert layout in ("linear", "paged"), \
            f"paged decode is linear-validity only, got layout={layout!r}"
        return _flash_decode_paged(q, k, v, pos, start, pages,
                                   softcap=softcap, scale=scale, dv=dv,
                                   interpret=interpret)
    B, H, dq = q.shape
    S, K = k.shape[1], k.shape[2]
    dv = dv or v.shape[-1]
    G = H // K
    scale = scale if scale is not None else dq ** -0.5
    pos = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (B,))
    start = (jnp.zeros((B,), jnp.int32) if start is None
             else jnp.broadcast_to(jnp.asarray(start, jnp.int32), (B,)))
    shared = v is k  # MLA dual-operand form: one cache array, two BlockSpecs
    if k.dtype != q.dtype:  # serving caches share the compute dtype: no-op
        k = k.astype(q.dtype)
    if shared:
        v = k
    elif v.dtype != q.dtype:
        v = v.astype(q.dtype)

    # sublane-align the per-kv-head query group; padded rows are sliced off
    # (block sizing — the largest sublane-aligned divisor of S when S % bk
    # is awkward — lives in fd_dense_spec so the prover sees the same grid)
    Gp = round_up(G, 8)
    qg = q.reshape(B, K, G, dq)
    if Gp != G:
        qg = jnp.pad(qg, ((0, 0), (0, 0), (0, Gp - G), (0, 0)))
    spec = fd_dense_spec(B, H, K, S, dq, dv, layout=layout, bk=bk)
    bk_ = spec.operands[1].block_shape[1]
    nk = spec.grid[2]
    Sp = nk * bk_
    if Sp != S:
        pads = ((0, 0), (0, Sp - S), (0, 0), (0, 0))
        k = jnp.pad(k, pads)
        v = k if shared else jnp.pad(v, pads)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,  # pos, start
        grid=spec.grid,
        in_specs=[pl.BlockSpec(op.block_shape, op.index_map)
                  for op in spec.inputs],
        out_specs=pl.BlockSpec(spec.outputs[0].block_shape,
                               spec.outputs[0].index_map),
        scratch_shapes=[
            pltpu.VMEM((Gp, 1), F32),
            pltpu.VMEM((Gp, 1), F32),
            pltpu.VMEM((Gp, dv), F32),
        ],
    )
    out = pl.pallas_call(
        functools.partial(_fd_kernel, nk=nk, bk=bk_, S=S, layout=layout,
                          softcap=softcap, scale=scale),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, K, Gp, dv), q.dtype),
        interpret=interpret,
    )(pos, start, qg, k, v)
    return out[:, :, :G].reshape(B, H, dv)
