"""Flash attention as a Pallas TPU kernel (online softmax, VMEM-blocked).

Block-wise attention is the paper's C1/C4 applied to the attention GEMM pair:
the (bq x bk) score tile never leaves VMEM, the running max/denominator are
the output-stationary accumulator state, and the KV block streaming is the
MOB prefetch pipeline.  Supports causal masking, sliding windows (Gemma-3
local layers), logit softcapping (Gemma-3 global layers) and GQA via
index-map head folding (no KV broadcast in HBM).  Ragged sequence lengths
are padded up to the block grid (padded keys masked, padded query rows
sliced off) the same way ``block_gemm`` pads ragged GEMMs.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import round_up
from repro.kernels.spec import KernelSpec, OperandSpec, ScalarSpec, provenance

F32 = jnp.float32
NEG = -1e30


def _paged_block_live(iq, ik, qs, kl, *, bq: int, ps: int):
    """Liveness of page ``ik`` for q-block ``iq``: the page holds valid rows
    and is not entirely past the block's causal horizon.  Shared between the
    kernel body (``pl.when``) and :func:`fa_paged_spec` (bounds prover)."""
    return (ik * ps < kl) & (ik * ps <= qs + (iq + 1) * bq - 1)


def fa_dense_spec(B: int, H: int, K: int, Sq: int, Sk: int, d: int, *,
                  bq: int = 128, bk: int = 128) -> KernelSpec:
    """Grid/BlockSpec contract of the dense ``flash_attention`` kernel."""
    G = H // K
    bq_, bk_ = min(bq, Sq), min(bk, Sk)
    Sqp, Skp = round_up(Sq, bq_), round_up(Sk, bk_)
    nk = Skp // bk_

    def q_map(bh, iq, ik):
        return (bh, iq, 0)

    def kv_map(bh, iq, ik):
        return ((bh // H) * K + (bh % H) // G, ik, 0)

    src_file, src_line = provenance(kv_map)
    return KernelSpec(
        name="flash_attention",
        grid=(B * H, Sqp // bq_, nk),
        scalars=(),
        operands=(
            OperandSpec("q", (1, bq_, d), q_map, (B * H, Sqp // bq_, 1)),
            OperandSpec("k", (1, bk_, d), kv_map, (B * K, nk, 1)),
            OperandSpec("v", (1, bk_, d), kv_map, (B * K, nk, 1)),
            OperandSpec("o", (1, bq_, d), q_map, (B * H, Sqp // bq_, 1),
                        is_output=True),
        ),
        block_live=None,  # every (q-block, k-block) pair is visited
        reduction_axes=(2,),
        src_file=src_file, src_line=src_line,
    )


def fa_paged_spec(B: int, H: int, K: int, C: int, d: int, ps: int, npp: int,
                  n_pages: int, *, bq: int = 128) -> KernelSpec:
    """Grid/BlockSpec contract of the paged chunk-prefill attention kernel.

    Scalar domains are hostile: ``q_start``/``k_len`` range over the full
    logical capacity (including ``k_len == 0`` — an empty chunk — and
    ``q_start == npp * ps``), and page-table entries over every pool page.
    """
    G = H // K
    bq_ = min(bq, round_up(C, 8))
    Cp = round_up(C, bq_)
    S = npp * ps

    def q_map(b, h, iq, ik, *_):
        return (b, h, iq, 0)

    def kv_map(b, h, iq, ik, qstart_ref, klen_ref, pages_ref):
        # dead logical pages revisit a live one (repeat index -> the DMA is
        # elided); the kernel gates their compute via _paged_block_live
        hi_k = (klen_ref[b] - 1) // ps
        hi_c = (qstart_ref[b] + (iq + 1) * bq_ - 1) // ps
        hi = jnp.clip(jnp.minimum(hi_k, hi_c), 0, npp - 1)
        ik = jnp.minimum(ik, hi)
        return (pages_ref[b, ik], 0, h // G, 0)

    def block_live(b, h, iq, ik, qstart, klen, pages):
        return _paged_block_live(iq, ik, qstart[b], klen[b], bq=bq_, ps=ps)

    src_file, src_line = provenance(kv_map)
    return KernelSpec(
        name="flash_attention_paged",
        grid=(B, H, Cp // bq_, npp),
        scalars=(
            ScalarSpec("q_start", (B,), 0, S),
            ScalarSpec("k_len", (B,), 0, S),
            ScalarSpec("pages", (B, npp), 0, n_pages - 1),
        ),
        operands=(
            OperandSpec("q", (1, 1, bq_, d), q_map, (B, H, Cp // bq_, 1)),
            OperandSpec("k", (1, ps, 1, d), kv_map, (n_pages, 1, K, 1)),
            OperandSpec("v", (1, ps, 1, d), kv_map, (n_pages, 1, K, 1)),
            OperandSpec("o", (1, 1, bq_, d), q_map, (B, H, Cp // bq_, 1),
                        is_output=True),
        ),
        block_live=block_live,
        reduction_axes=(3,),
        src_file=src_file, src_line=src_line,
    )


def _fa_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
               nk: int, bq: int, bk: int, sq: int, sk: int, H: int,
               scale: float, causal: bool, window: int, softcap: float):
    """One (batch*head, q-block, k-block) grid step.

    ``sq``/``sk`` are the *unpadded* sequence lengths: the query-position
    offset aligns the last real query with the last real key (``sq < sk``
    is the suffix-prefill pattern — queries continue a cached prefix), and
    key columns at ``kpos >= sk`` are grid padding that must never receive
    weight.
    """
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0]
    k = k_ref[0]
    v = v_ref[0]
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=F32) * scale  # [bq, bk]
    if softcap:
        s = jnp.tanh(s / softcap) * softcap

    iq = pl.program_id(1)
    qpos = iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0) + (sk - sq)
    kpos = ik * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = kpos < sk  # grid padding: ragged Sk rounded up to bk
    if causal:
        mask &= kpos <= qpos
    if window:
        mask &= kpos > qpos - window
    s = jnp.where(mask, s, NEG)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, s.max(-1, keepdims=True))
    p = jnp.exp(s - m_new)
    # rows with every key masked so far keep m_new == NEG, where the update
    # above degenerates to exp(0) == 1 per masked entry (mean(V) instead of
    # zeros); zero their probabilities so l stays 0 and the store emits 0.
    p = jnp.where(m_new > NEG * 0.5, p, 0.0)
    alpha = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * alpha + p.sum(-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot(
        p.astype(v.dtype), v, preferred_element_type=F32)
    m_ref[...] = m_new

    @pl.when(ik == nk - 1)
    def _store():
        o_ref[0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


def _fa_kernel_paged(qstart_ref, klen_ref, pages_ref, q_ref, k_ref, v_ref,
                     o_ref, m_ref, l_ref, acc_ref, *, nk: int, bq: int,
                     ps: int, window: int, softcap: float, scale: float):
    """One (batch, head, q-block, page) grid step of chunked-prefill
    attention over a paged past.

    The query chunk's rows sit at absolute logical positions
    ``qstart[b] + i`` and attend causally over logical rows
    ``[0, klen[b])`` of the page pool — which include the chunk's own keys,
    written through the page table before the kernel runs.  The page table
    itself is consumed only by the BlockSpec index map (``pages_ref`` never
    appears here): the kernel body works in logical rows, exactly like the
    dense ``_fa_kernel``, with validity from the prefetched scalars instead
    of a suffix-alignment offset."""
    del pages_ref
    b = pl.program_id(0)
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    qs = qstart_ref[b]
    kl = klen_ref[b]
    # skip pages past the valid rows or past this q-block's causal horizon;
    # their DMA was already elided by the index-map clip, never read them.
    block_live = _paged_block_live(iq, ik, qs, kl, bq=bq, ps=ps)

    @pl.when(block_live)
    def _block():
        q = q_ref[0, 0]       # [bq, d]
        k = k_ref[0, :, 0]    # [ps, d]  (pool-native [P, ps, K, d] layout)
        v = v_ref[0, :, 0]    # [ps, d]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=F32) * scale
        if softcap:
            s = jnp.tanh(s / softcap) * softcap
        qpos = qs + iq * bq + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        kpos = ik * ps + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = (kpos < kl) & (kpos <= qpos)
        if window:
            mask &= kpos > qpos - window
        s = jnp.where(mask, s, NEG)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(-1, keepdims=True))
        p = jnp.exp(s - m_new)
        p = jnp.where(m_new > NEG * 0.5, p, 0.0)  # all-masked rows stay zero
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + p.sum(-1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot(
            p.astype(v.dtype), v, preferred_element_type=F32)
        m_ref[...] = m_new

    @pl.when(ik == nk - 1)
    def _store():
        o_ref[0, 0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)
                       ).astype(o_ref.dtype)


def _flash_attention_paged(q, k, v, pages, q_start, k_len, *, window: int,
                           softcap: float, scale, bq: int, interpret: bool):
    """q: [B, H, C, d] query chunk; k/v: page pools [P, ps, K, d];
    pages: [B, npp] int32 -> [B, H, C, d].

    Chunked-prefill attention: logical row ``r`` of slot ``b`` lives at pool
    row ``(pages[b, r // ps], r % ps)``; query row ``i`` sits at logical
    position ``q_start[b] + i`` and rows ``[0, k_len[b])`` are valid.  One
    k-block is one page and the BlockSpec index map follows the
    scalar-prefetched table (the ``flash_decode`` paged trick): dead pages —
    beyond the valid rows or beyond the q-block's causal horizon — are
    remapped to a live page index so the repeated-visit DMA is elided, and
    their compute is skipped in-kernel."""
    B, H, C, d = q.shape
    ps, K = k.shape[1], k.shape[2]
    npp = pages.shape[1]
    scale = scale if scale is not None else d ** -0.5
    q_start = jnp.broadcast_to(jnp.asarray(q_start, jnp.int32), (B,))
    k_len = jnp.broadcast_to(jnp.asarray(k_len, jnp.int32), (B,))
    pages = jnp.asarray(pages, jnp.int32)
    if k.dtype != q.dtype:  # serving pools share the compute dtype: no-op
        k = k.astype(q.dtype)
    if v.dtype != q.dtype:
        v = v.astype(q.dtype)

    spec = fa_paged_spec(B, H, K, C, d, ps, npp, k.shape[0], bq=bq)
    bq_ = spec.outputs[0].block_shape[2]
    Cp = spec.grid[2] * bq_
    if Cp != C:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, Cp - C), (0, 0)))

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,  # q_start, k_len, pages
        grid=spec.grid,
        in_specs=[pl.BlockSpec(op.block_shape, op.index_map)
                  for op in spec.inputs],
        out_specs=pl.BlockSpec(spec.outputs[0].block_shape,
                               spec.outputs[0].index_map),
        scratch_shapes=[
            pltpu.VMEM((bq_, 1), F32),
            pltpu.VMEM((bq_, 1), F32),
            pltpu.VMEM((bq_, d), F32),
        ],
    )
    out = pl.pallas_call(
        functools.partial(_fa_kernel_paged, nk=npp, bq=bq_, ps=ps,
                          window=window, softcap=softcap, scale=scale),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, H, Cp, d), q.dtype),
        interpret=interpret,
    )(q_start, k_len, pages, q, k, v)
    return out[:, :, :C]


def flash_attention(q, k, v, *, causal=True, window=0, bq=128, bk=128,
                    scale=None, softcap=0.0, pages=None, q_start=None,
                    k_len=None, interpret=False):
    """q: [B,H,Sq,d]; k/v: [B,K,Sk,d] with H % K == 0 (GQA folded in the
    BlockSpec index map).  Arbitrary Sq/Sk: ragged shapes are padded up to
    the block grid and sliced back (padded keys are masked out in-kernel).
    Fully-masked rows return zeros.

    ``pages`` switches to the *paged past* layout for chunked prefill: k/v
    become page pools ``[n_pages, page_size, K, d]``, ``pages`` the [B, npp]
    page table, and ``q_start``/``k_len`` [B] give the chunk's first query
    position and the valid logical row count (see
    :func:`_flash_attention_paged`).  Paged attention is causal by
    definition — the chunk continues a causal prefix."""
    if pages is not None:
        assert causal, "paged chunk-prefill attention is causal by definition"
        return _flash_attention_paged(q, k, v, pages, q_start, k_len,
                                      window=window, softcap=softcap,
                                      scale=scale, bq=bq, interpret=interpret)
    B, H, Sq, d = q.shape
    K = k.shape[1]
    Sk = k.shape[2]
    spec = fa_dense_spec(B, H, K, Sq, Sk, d, bq=bq, bk=bk)
    bq_ = spec.operands[0].block_shape[1]
    bk_ = spec.operands[1].block_shape[1]
    Sqp, Skp = spec.grid[1] * bq_, spec.grid[2] * bk_
    if Sqp != Sq:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, Sqp - Sq), (0, 0)))
    if Skp != Sk:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, Skp - Sk), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, Skp - Sk), (0, 0)))
    scale = scale if scale is not None else d ** -0.5
    qf = q.reshape(B * H, Sqp, d)
    kf = k.reshape(B * K, Skp, d)
    vf = v.reshape(B * K, Skp, d)
    nk = spec.grid[2]

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=0,
        grid=spec.grid,
        in_specs=[pl.BlockSpec(op.block_shape, op.index_map)
                  for op in spec.inputs],
        out_specs=pl.BlockSpec(spec.outputs[0].block_shape,
                               spec.outputs[0].index_map),
        scratch_shapes=[
            pltpu.VMEM((bq_, 1), F32),
            pltpu.VMEM((bq_, 1), F32),
            pltpu.VMEM((bq_, d), F32),
        ],
    )
    out = pl.pallas_call(
        functools.partial(_fa_kernel, nk=nk, bq=bq_, bk=bk_, sq=Sq, sk=Sk,
                          H=H, scale=scale, causal=causal, window=window,
                          softcap=softcap),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B * H, Sqp, d), q.dtype),
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(B, H, Sqp, d)[:, :, :Sq]
