"""Flash attention as a Pallas TPU kernel (online softmax, VMEM-blocked).

Block-wise attention is the paper's C1/C4 applied to the attention GEMM pair:
the (bq x bk) score tile never leaves VMEM, the running max/denominator are
the output-stationary accumulator state, and the KV block streaming is the
MOB prefetch pipeline.  Supports causal masking, sliding windows (Gemma-3
local layers), logit softcapping (Gemma-3 global layers) and GQA via
index-map head folding (no KV broadcast in HBM).  Ragged sequence lengths
are padded up to the block grid (padded keys masked, padded query rows
sliced off) the same way ``block_gemm`` pads ragged GEMMs.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import round_up

F32 = jnp.float32
NEG = -1e30


def _fa_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
               nk: int, bq: int, bk: int, sq: int, sk: int, H: int,
               scale: float, causal: bool, window: int, softcap: float):
    """One (batch*head, q-block, k-block) grid step.

    ``sq``/``sk`` are the *unpadded* sequence lengths: the query-position
    offset aligns the last real query with the last real key (``sq < sk``
    is the suffix-prefill pattern — queries continue a cached prefix), and
    key columns at ``kpos >= sk`` are grid padding that must never receive
    weight.
    """
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0]
    k = k_ref[0]
    v = v_ref[0]
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=F32) * scale  # [bq, bk]
    if softcap:
        s = jnp.tanh(s / softcap) * softcap

    iq = pl.program_id(1)
    qpos = iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0) + (sk - sq)
    kpos = ik * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = kpos < sk  # grid padding: ragged Sk rounded up to bk
    if causal:
        mask &= kpos <= qpos
    if window:
        mask &= kpos > qpos - window
    s = jnp.where(mask, s, NEG)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, s.max(-1, keepdims=True))
    p = jnp.exp(s - m_new)
    # rows with every key masked so far keep m_new == NEG, where the update
    # above degenerates to exp(0) == 1 per masked entry (mean(V) instead of
    # zeros); zero their probabilities so l stays 0 and the store emits 0.
    p = jnp.where(m_new > NEG * 0.5, p, 0.0)
    alpha = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * alpha + p.sum(-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot(
        p.astype(v.dtype), v, preferred_element_type=F32)
    m_ref[...] = m_new

    @pl.when(ik == nk - 1)
    def _store():
        o_ref[0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


def flash_attention(q, k, v, *, causal=True, window=0, bq=128, bk=128,
                    scale=None, softcap=0.0, interpret=False):
    """q: [B,H,Sq,d]; k/v: [B,K,Sk,d] with H % K == 0 (GQA folded in the
    BlockSpec index map).  Arbitrary Sq/Sk: ragged shapes are padded up to
    the block grid and sliced back (padded keys are masked out in-kernel).
    Fully-masked rows return zeros."""
    B, H, Sq, d = q.shape
    K = k.shape[1]
    Sk = k.shape[2]
    G = H // K
    bq_, bk_ = min(bq, Sq), min(bk, Sk)
    Sqp, Skp = round_up(Sq, bq_), round_up(Sk, bk_)
    if Sqp != Sq:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, Sqp - Sq), (0, 0)))
    if Skp != Sk:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, Skp - Sk), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, Skp - Sk), (0, 0)))
    scale = scale if scale is not None else d ** -0.5
    qf = q.reshape(B * H, Sqp, d)
    kf = k.reshape(B * K, Skp, d)
    vf = v.reshape(B * K, Skp, d)
    nk = Skp // bk_
    grid = (B * H, Sqp // bq_, nk)

    def kv_map(bh, iq, ik):
        return ((bh // H) * K + (bh % H) // G, ik, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=0,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq_, d), lambda bh, iq, ik: (bh, iq, 0)),
            pl.BlockSpec((1, bk_, d), kv_map),
            pl.BlockSpec((1, bk_, d), kv_map),
        ],
        out_specs=pl.BlockSpec((1, bq_, d), lambda bh, iq, ik: (bh, iq, 0)),
        scratch_shapes=[
            pltpu.VMEM((bq_, 1), F32),
            pltpu.VMEM((bq_, 1), F32),
            pltpu.VMEM((bq_, d), F32),
        ],
    )
    out = pl.pallas_call(
        functools.partial(_fa_kernel, nk=nk, bq=bq_, bk=bk_, sq=Sq, sk=Sk,
                          H=H, scale=scale, causal=causal, window=window,
                          softcap=softcap),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B * H, Sqp, d), q.dtype),
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(B, H, Sqp, d)[:, :, :Sq]
