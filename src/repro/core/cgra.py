"""The paper's CGRA, as configuration + first-order analytical simulator.

This is the *faithful-reproduction* layer: the paper (qualitative) claims that

  C1  a 4x4 PE array executes block-wise GEMM in parallel,
  C2  a 4x2 MOB array decouples LOAD/STORE from compute (fewer PE stalls),
  C3  a switchless mesh-torus interconnect cuts dynamic power/latency vs a
      switched NoC,
  C4  block-wise execution increases data reuse and cuts external-memory
      bandwidth.

The simulator quantifies all four with first-order cycle/energy models
(28nm-class constants, Horowitz ISSCC'14 lineage), and the same
``CGRAConfig`` doubles as the *tile-shape selector* for the TPU Pallas
kernels (``repro.kernels``): the PE-array geometry generalizes to the MXU
tile and the MOB double-buffering to the Pallas HBM->VMEM pipeline.
"""
from __future__ import annotations

import math
from dataclasses import dataclass


@dataclass(frozen=True)
class CGRAConfig:
    # heterogeneous array (paper Fig. 2)
    pe_rows: int = 4
    pe_cols: int = 4
    mob_rows: int = 4
    mob_cols: int = 2
    # each PE: one packed MAC per cycle; int8 packs 4 lanes into a 32b word
    pack: dict | None = None  # dtype -> lanes
    rf_words: int = 16  # per-PE output-register words (virtual block tiling)
    freq_mhz: float = 100.0
    # interconnect
    switched_noc: bool = False  # paper baseline comparison
    hop_cycles_switchless: int = 1
    hop_cycles_switched: int = 3
    # MOB decoupling (C2): double-buffered prefetch overlaps mem with compute
    decoupled_mob: bool = True
    # first-order energy constants, pJ (28nm-class)
    e_mac: dict | None = None  # per dtype, pJ / MAC
    e_sram_word: float = 5.0  # shared-L1 access via MOB, 32-bit word
    e_hop_word: float = 0.15  # switchless neighbor link, per word per hop
    e_router_word: float = 0.6  # extra per-hop router cost when switched
    e_pe_idle_cycle: float = 0.05  # leakage+clock per PE per cycle
    e_ctrl_cycle: float = 1.0  # array-level control per cycle

    def __post_init__(self):
        if self.pack is None:
            object.__setattr__(self, "pack", {"int8": 4, "fp16": 2, "fp32": 1})
        if self.e_mac is None:
            object.__setattr__(self, "e_mac", {"int8": 0.2, "fp16": 0.9, "fp32": 3.0})

    @property
    def n_pe(self) -> int:
        return self.pe_rows * self.pe_cols

    @property
    def n_mob(self) -> int:
        return self.mob_rows * self.mob_cols

    @property
    def words_per_cycle(self) -> int:  # one 32-bit LOAD/STORE per MOB per cycle
        return self.n_mob

    @property
    def hop_cycles(self) -> int:
        return self.hop_cycles_switched if self.switched_noc else self.hop_cycles_switchless

    @property
    def mean_hops(self) -> float:
        """Mean torus hop distance PE<->MOB/PE (torus wrap halves distances)."""
        r = (self.pe_rows // 2 + self.pe_cols // 2) / 2
        return max(1.0, r)


@dataclass
class GemmReport:
    M: int
    K: int
    N: int
    dtype: str
    bm: int
    bn: int
    macs: int = 0
    cycles: int = 0
    compute_cycles: int = 0
    mem_cycles: int = 0
    stall_cycles: int = 0
    loads_words: int = 0
    stores_words: int = 0
    hops_words: float = 0.0
    energy_pj: float = 0.0
    time_us: float = 0.0
    power_mw: float = 0.0
    pe_utilization: float = 0.0
    arithmetic_intensity: float = 0.0  # MACs per word moved

    def combine(self, other: "GemmReport") -> "GemmReport":
        out = GemmReport(self.M, self.K, self.N, self.dtype, self.bm, self.bn)
        for f in ("macs", "cycles", "compute_cycles", "mem_cycles", "stall_cycles",
                  "loads_words", "stores_words", "hops_words", "energy_pj",
                  "time_us"):
            setattr(out, f, getattr(self, f) + getattr(other, f))
        tot = out.time_us
        out.power_mw = (out.energy_pj / 1e6) / (tot / 1e6) / 1e3 if tot else 0.0
        out.pe_utilization = out.compute_cycles / max(1, out.cycles)
        words = out.loads_words + out.stores_words
        out.arithmetic_intensity = out.macs / max(1, words)
        return out


def block_shape(cfg: CGRAConfig, dtype: str = "int8") -> tuple[int, int]:
    """Output block computed per pass: the PE grid times the per-PE register
    tile (virtual blocking, C4).  rf_words split between a square-ish rm x rn."""
    rm = max(1, int(math.sqrt(cfg.rf_words)))
    rn = max(1, cfg.rf_words // rm)
    return cfg.pe_rows * rm, cfg.pe_cols * rn


def simulate_gemm(cfg: CGRAConfig, M: int, K: int, N: int,
                  dtype: str = "int8", blocked: bool = True) -> GemmReport:
    """First-order simulation of C = A[M,K] @ B[K,N] on the CGRA.

    ``blocked=False`` models the naive dataflow (each output element streams
    its full row/col with no reuse) — the paper's implicit baseline for C4.
    """
    pack = cfg.pack.get(dtype, 1)
    bm, bn = block_shape(cfg, dtype) if blocked else (1, 1)
    bm, bn = min(bm, M), min(bn, N)
    n_blocks = math.ceil(M / bm) * math.ceil(N / bn)

    rep = GemmReport(M, K, N, dtype, bm, bn)
    rep.macs = M * N * K

    # per block: stream K steps; each step needs bm + bn input words (packed)
    words_in_per_block = (bm + bn) * math.ceil(K / pack)
    words_out_per_block = bm * bn  # int32/fp32 accumulator written back
    rep.loads_words = words_in_per_block * n_blocks
    rep.stores_words = words_out_per_block * n_blocks

    # compute: PE array does n_pe MACs/cycle on packed lanes
    rep.compute_cycles = math.ceil(rep.macs / (cfg.n_pe * pack))
    # memory: MOBs move words_per_cycle words/cycle
    total_words = rep.loads_words + rep.stores_words
    rep.mem_cycles = math.ceil(total_words / cfg.words_per_cycle)

    fill = int(cfg.mean_hops * cfg.hop_cycles) * n_blocks  # pipeline fill per block
    if cfg.decoupled_mob:
        # C2: LOAD/STORE runs ahead of compute; slower side bounds throughput
        rep.cycles = max(rep.compute_cycles, rep.mem_cycles) + fill
    else:
        rep.cycles = rep.compute_cycles + rep.mem_cycles + fill
    rep.stall_cycles = rep.cycles - rep.compute_cycles

    # interconnect traffic: every input word traverses mean_hops links
    rep.hops_words = total_words * cfg.mean_hops

    e_link = cfg.e_hop_word + (cfg.e_router_word if cfg.switched_noc else 0.0)
    rep.energy_pj = (
        rep.macs * cfg.e_mac[dtype]
        + total_words * cfg.e_sram_word
        + rep.hops_words * e_link
        + rep.stall_cycles * cfg.n_pe * cfg.e_pe_idle_cycle
        + rep.cycles * cfg.e_ctrl_cycle
    )
    rep.time_us = rep.cycles / cfg.freq_mhz
    rep.power_mw = (rep.energy_pj / 1e6) / (rep.time_us / 1e6) / 1e3 if rep.time_us else 0.0
    rep.pe_utilization = rep.compute_cycles / max(1, rep.cycles)
    rep.arithmetic_intensity = rep.macs / max(1, total_words)
    return rep


def transformer_gemms(d_model: int, n_heads: int, head_dim: int, d_ff: int,
                      seq: int, vocab: int = 0) -> list[tuple[str, int, int, int]]:
    """The GEMM set of one decoder layer at sequence length `seq` (inference)."""
    H = n_heads * head_dim
    gemms = [
        ("wq", seq, d_model, H),
        ("wk", seq, d_model, H),
        ("wv", seq, d_model, H),
        ("scores", seq * n_heads, head_dim, seq),
        ("attnv", seq * n_heads, seq, head_dim),
        ("wo", seq, H, d_model),
        ("ffn_up", seq, d_model, d_ff),
        ("ffn_gate", seq, d_model, d_ff),
        ("ffn_down", seq, d_ff, d_model),
    ]
    if vocab:
        gemms.append(("lm_head", seq, d_model, vocab))
    return gemms


def simulate_transformer_layer(cfg: CGRAConfig, d_model: int, n_heads: int,
                               head_dim: int, d_ff: int, seq: int,
                               dtype: str = "int8", blocked: bool = True):
    reports = {}
    total = None
    for name, m, k, n in transformer_gemms(d_model, n_heads, head_dim, d_ff, seq):
        r = simulate_gemm(cfg, m, k, n, dtype, blocked)
        reports[name] = r
        total = r if total is None else total.combine(r)
    return total, reports


# ---------------------------------------------------------------------------
# TPU tile-shape selection — the CGRA "mapper" generalized to the MXU.
# ---------------------------------------------------------------------------

TPU_VMEM_BYTES = 16 * 1024 * 1024  # v5e per-core VMEM
MXU_DIM = 128


def select_block_shapes(M: int, K: int, N: int, dtype_bytes: int = 2,
                        vmem_budget: int = TPU_VMEM_BYTES // 2,
                        acc_bytes: int = 4) -> tuple[int, int, int]:
    """Pick (bm, bk, bn), multiples of the MXU dim, maximizing data reuse
    (large bm x bn output blocks) subject to double-buffered VMEM residency:
        2*(bm*bk + bk*bn)*dtype_bytes + bm*bn*acc_bytes <= vmem_budget.

    This is the same mapping decision the paper's Memory Controller makes for
    the 4x4 array, scaled to VMEM/MXU. (C1/C4)
    """
    def fits(bm, bk, bn):
        return 2 * (bm * bk + bk * bn) * dtype_bytes + bm * bn * acc_bytes <= vmem_budget

    def clamp(x, cap):
        return max(MXU_DIM, min(((x + MXU_DIM - 1) // MXU_DIM) * MXU_DIM, cap))

    best = (MXU_DIM, MXU_DIM, MXU_DIM)
    best_reuse = -1.0
    caps = (clamp(M, 4096), clamp(K, 4096), clamp(N, 4096))
    for bm in range(MXU_DIM, caps[0] + 1, MXU_DIM):
        for bn in range(MXU_DIM, caps[2] + 1, MXU_DIM):
            for bk in (MXU_DIM, 2 * MXU_DIM, 4 * MXU_DIM, 8 * MXU_DIM):
                if bk > caps[1] or not fits(bm, bk, bn):
                    continue
                # reuse metric: MACs per input word moved
                reuse = (bm * bn * bk) / (bm * bk + bk * bn)
                if reuse > best_reuse:
                    best_reuse, best = reuse, (bm, bk, bn)
    return best
