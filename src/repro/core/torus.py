"""Switchless-torus collective schedules (paper claim C3, pod scale).

TPU ICI *is* a switchless torus; the paper's insight — schedule data movement
as neighbor-only hops that overlap with compute — maps onto
``lax.ppermute`` ring schedules inside ``shard_map``.  These replace XLA's
monolithic all-gather / all-reduce with tp-1 neighbor permutes, each
overlappable with the partial GEMM it feeds (the MOB decoupling, C2, at pod
scale).

All functions are written to run *inside* ``shard_map`` over ``axis_name``.
``tests/test_torus.py`` validates them against dense references on a fake
8-device mesh; ``benchmarks/interconnect.py`` compares the lowered HLO
collective schedule against the XLA default.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import Mesh, PartitionSpec as P

try:  # moved out of experimental in newer jax releases
    from jax.experimental.shard_map import shard_map
except ImportError:
    from jax import shard_map


def axis_size(axis_name) -> int:
    """Static named-axis size; ``lax.axis_size`` only exists in newer jax
    (``psum(1, axis)`` is folded to a concrete int inside shard_map)."""
    if hasattr(lax, "axis_size"):
        return lax.axis_size(axis_name)
    return lax.psum(1, axis_name)


def _ring_perm(axis_name, shift=1):
    n = axis_size(axis_name)
    return [(i, (i + shift) % n) for i in range(n)]


def ring_allgather_matmul(x_shard, w_local, axis_name="model"):
    """Y = X @ W, X sharded over rows (tokens), W sharded over cols.

    x_shard: [Tl, D] (this device's token chunk), w_local: [D, Fl].
    Returns Y_full_rows: [tp*Tl, Fl] — every token row, local feature shard.

    Instead of all-gather(X) followed by one big GEMM, the torus schedule
    rotates token chunks around the ring: at step s the device multiplies the
    chunk it currently holds while the next chunk is in flight on the
    neighbor link (overlap).  Bytes on the wire equal the all-gather, but
    every transfer is a single switchless neighbor hop.
    """
    tp = axis_size(axis_name)
    idx = lax.axis_index(axis_name)
    Tl, D = x_shard.shape
    Fl = w_local.shape[1]
    out = jnp.zeros((tp * Tl, Fl), w_local.dtype)
    cur = x_shard
    perm = _ring_perm(axis_name)
    for s in range(tp):
        part = jnp.matmul(cur, w_local)  # [Tl, Fl]
        src = (idx - s) % tp  # whose chunk we just multiplied (perm i -> i+1)
        out = lax.dynamic_update_slice(out, part.astype(out.dtype), (src * Tl, 0))
        if s < tp - 1:
            cur = lax.ppermute(cur, axis_name, perm)
    return out


def matmul_reducescatter_ring(h_full, w_local, axis_name="model"):
    """Y_shard = reduce_scatter_rows( H @ W_partial ).

    h_full: [T, Fl] (local feature shard of all tokens), w_local: [Fl, D].
    Returns: [T/tp, D] — this device's token chunk of the summed output.

    Ring reduce-scatter: the accumulator for token chunk c travels the ring,
    gathering each device's partial GEMM for that chunk — tp-1 neighbor hops,
    each overlapped with the next partial GEMM.
    """
    tp = axis_size(axis_name)
    idx = lax.axis_index(axis_name)
    T, Fl = h_full.shape
    Tl = T // tp
    perm = _ring_perm(axis_name, shift=1)

    def chunk_mm(c):
        hc = lax.dynamic_slice(h_full, (c * Tl, 0), (Tl, Fl))
        return jnp.matmul(hc, w_local)  # [Tl, D]

    # the accumulator that ends on device i starts at device i+1 carrying
    # chunk i; a device visited at hop s therefore adds chunk (idx - s - 1)
    acc = chunk_mm((idx - 1) % tp)
    for s in range(1, tp):
        acc = lax.ppermute(acc, axis_name, perm)
        acc = acc + chunk_mm((idx - s - 1) % tp)
    return acc  # == sum over devices of chunk `idx`


def ring_allreduce(x, axis_name="model"):
    """Bidirectional-ring all-reduce via ppermute (reduce-scatter + all-gather
    on flattened chunks).  Used where we want the collective expressed as
    neighbor hops (e.g. to prove C3 schedules) rather than XLA's all-reduce."""
    tp = axis_size(axis_name)
    flat = x.reshape(-1)
    pad = (-flat.shape[0]) % tp
    flat = jnp.pad(flat, (0, pad))
    chunks = flat.reshape(tp, -1)
    idx = lax.axis_index(axis_name)
    perm = _ring_perm(axis_name)

    # reduce-scatter
    acc = jnp.take(chunks, (idx - 1) % tp, axis=0)
    for s in range(1, tp):
        acc = lax.ppermute(acc, axis_name, perm)
        acc = acc + jnp.take(chunks, (idx - s - 1) % tp, axis=0)
    # all-gather
    out = jnp.zeros_like(chunks)
    cur = acc
    for s in range(tp):
        src = (idx - s) % tp
        out = jnp.where(jnp.arange(tp)[:, None] == src, cur[None], out)
        if s < tp - 1:
            cur = lax.ppermute(cur, axis_name, perm)
    res = out.reshape(-1)
    if pad:
        res = res[:-pad]
    return res.reshape(x.shape)


# ---------------------------------------------------------------------------
# Drop-in torus tensor-parallel FFN (sequence-parallel in, sequence-parallel
# out).  Used by the perf hillclimb via cfg.use_torus_tp.
# ---------------------------------------------------------------------------

def torus_ffn(x, w_gate, w_up, w_down, mesh: Mesh, axis_name="model",
              act=jax.nn.silu):
    """x: [B, S, D] (replicated over `axis_name`); weights sharded on the ffn
    dim.  Computes SwiGLU FFN with ring-scheduled collectives only."""

    def inner(xs, wg, wu, wd):
        B, Sl, D = xs.shape
        xf = xs.reshape(B * Sl, D)
        g = ring_allgather_matmul(xf, wg, axis_name)
        u = ring_allgather_matmul(xf, wu, axis_name)
        h = act(g) * u  # [B*S, Fl]
        y = matmul_reducescatter_ring(h, wd, axis_name)  # [B*Sl, D]
        return y.reshape(B, Sl, D)

    spec_x = P(None, axis_name, None)
    spec_w_col = P(None, axis_name)
    spec_w_row = P(axis_name, None)
    fn = shard_map(inner, mesh=mesh,
                   in_specs=(spec_x, spec_w_col, spec_w_col, spec_w_row),
                   out_specs=spec_x)
    return fn(x, w_gate, w_up, w_down)
