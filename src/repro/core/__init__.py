# The paper's primary contribution — implement the SYSTEM here
# (scheduler, optimizer, data path, serving loop, etc.) in the
# host framework. Add sibling subpackages for substrates.


def round_up(n: int, m: int) -> int:
    """Smallest multiple of ``m`` that is >= ``n`` (block-grid alignment)."""
    return ((n + m - 1) // m) * m
