"""Block-wise GEMM public API (paper C1/C4).

``cgra_gemm`` is the framework's single GEMM entry point: model layers route
through it, the mode flag selects the reference jnp path (dry-run / oracle),
the Pallas interpret path (CPU validation) or the compiled TPU kernel.  The
int8 path covers the paper's packed-data edge-inference scenario end to end
(quantize -> packed GEMM -> fused dequant)."""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.quant import QTensor, quantize
from repro.kernels.ops import cgra_matmul, cgra_matmul_int8


def cgra_gemm(a, b, mode: str = "reference", out_dtype=None):
    """C = A[..., M, K] @ B[K, N]; leading batch dims of A are flattened.

    ``out_dtype`` selects the store dtype of the f32 accumulator (default:
    ``a.dtype``) — full-precision consumers request f32 directly instead of
    round-tripping through the compute dtype."""
    lead = a.shape[:-1]
    a2 = a.reshape(-1, a.shape[-1])
    out = cgra_matmul(a2, b, mode, out_dtype)
    return out.reshape(*lead, b.shape[-1])


def cgra_gemm_w8a8(x, w_q: QTensor, mode: str = "reference",
                   out_dtype=jnp.float32):
    """Dynamic-activation int8 GEMM: quantize x per-row, packed GEMM against
    pre-quantized weights (per-col scales)."""
    lead = x.shape[:-1]
    x2 = x.reshape(-1, x.shape[-1])
    x_q = quantize(x2, axis=0)  # per-row scales [M,1]
    w_scale = w_q.scale.reshape(1, -1)
    out = cgra_matmul_int8(x_q.q, w_q.q, x_q.scale, w_scale, mode, out_dtype)
    return out.reshape(*lead, w_q.q.shape[-1])
