"""Cache-layout vocabulary shared by the kernels, layers and serving engine.

``CacheLayout`` replaces the loose ``"linear"``/``"ring"`` strings that the
decode kernels grew across PRs 1-3.  It subclasses ``str`` so every existing
comparison (``layout == "linear"``) and every caller passing a plain string
keeps working; new code should pass the enum members.

- ``LINEAR`` — global-attention cache: rows ``[start, pos]`` are live, row
  ``pos`` holds the current token.
- ``RING``   — sliding-window cache of size S: entry ``j`` holds absolute row
  ``pos - ((pos - j) mod S)``.
- ``PAGED``  — block-table cache: logical rows ``[start, pos]`` live, mapped
  through a per-sequence page table onto a shared page pool (the serving
  engine's layout; the kernels see it as LINEAR plus a page indirection).
- ``STATE``  — constant-size recurrent state (SSM); no row indexing at all.
"""
from __future__ import annotations

from enum import Enum


class CacheLayout(str, Enum):
    LINEAR = "linear"
    RING = "ring"
    PAGED = "paged"
    STATE = "state"

    def __str__(self) -> str:  # f"{layout}" -> "linear", not "CacheLayout..."
        return self.value
