"""int8 quantization — the paper's "packed data" path (C1) plus the
error-feedback gradient compressor used for cross-pod data parallelism."""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

F32 = jnp.float32


class QTensor(NamedTuple):
    q: jax.Array  # int8
    scale: jax.Array  # f32, per-channel over the last dim (or scalar)


def quantize(x, axis: int | None = -1) -> QTensor:
    """Symmetric int8 quantization with per-channel scales along `axis`."""
    xf = x.astype(F32)
    if axis is None:
        amax = jnp.max(jnp.abs(xf))
    else:
        red = tuple(i for i in range(xf.ndim) if i != (axis % xf.ndim))
        amax = jnp.max(jnp.abs(xf), axis=red, keepdims=True)
    scale = jnp.maximum(amax, 1e-8) / 127.0
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    return QTensor(q, scale.astype(F32))


def dequantize(qt: QTensor, dtype=F32):
    return (qt.q.astype(F32) * qt.scale).astype(dtype)


def quantized_matmul_ref(x_q: QTensor, w_q: QTensor, out_dtype=F32):
    """(x_scale * x_q) @ (w_q * w_scale) with int32 accumulation.

    x_q.q: [..., K] (per-row scales), w_q.q: [K, N] (per-col scales)."""
    acc = jnp.matmul(x_q.q.astype(jnp.int32), w_q.q.astype(jnp.int32))
    return (acc.astype(F32) * x_q.scale * w_q.scale).astype(out_dtype)


# ---------------------------------------------------------------------------
# Error-feedback int8 gradient compression (cross-pod DP all-reduce)
# ---------------------------------------------------------------------------

def compress_grad(g, err):
    """Returns (q: QTensor with scalar scale, new_err).  `err` carries the
    quantization residual into the next step (error feedback), which keeps
    SGD/Adam convergence unbiased to first order."""
    gf = g.astype(F32) + err
    qt = quantize(gf, axis=None)
    deq = dequantize(qt)
    return qt, gf - deq


def decompress_grad(qt: QTensor, dtype=F32):
    return dequantize(qt, dtype)
