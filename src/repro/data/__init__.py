from repro.data.pipeline import SyntheticLM, prefetching  # noqa: F401
