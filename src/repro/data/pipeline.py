"""Deterministic synthetic data pipeline, DP-sharded, with prefetch.

Stateless by design: ``batch_at(step)`` is a pure function of (seed, step),
so checkpoint-restart and elastic re-sharding resume the exact token stream
with no data-loader state to persist — the fault-tolerance property real
frameworks get from deterministic samplers.

The synthetic LM stream is a mixture of Zipf-distributed tokens and
copy/induction patterns, giving a small model something learnable (the
quickstart example's loss visibly drops).
"""
from __future__ import annotations

import queue
import threading
from typing import Iterator

import numpy as np

import jax

from repro.configs.base import ArchConfig


class SyntheticLM:
    def __init__(self, cfg: ArchConfig, batch: int, seq: int, seed: int = 0):
        self.cfg, self.batch, self.seq, self.seed = cfg, batch, seq, seed
        v = cfg.vocab_size
        ranks = np.arange(1, v + 1, dtype=np.float64)
        self.zipf = (1.0 / ranks) / np.sum(1.0 / ranks)

    def batch_at(self, step: int) -> dict:
        rng = np.random.default_rng((self.seed, step))
        B, S, v = self.batch, self.seq + 1, self.cfg.vocab_size
        toks = rng.choice(v, size=(B, S), p=self.zipf).astype(np.int32)
        # induction heads: repeat a random span later in the sequence
        span = max(4, S // 16)
        for b in range(B):
            src = rng.integers(0, S - 2 * span)
            dst = rng.integers(src + span, S - span)
            toks[b, dst:dst + span] = toks[b, src:src + span]
        batch = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
        if self.cfg.vision_tokens:
            batch["images"] = rng.standard_normal(
                (B, self.cfg.vision_tokens, self.cfg.vision_dim)).astype(np.float32)
        if self.cfg.audio_frontend:
            batch["frames"] = rng.standard_normal(
                (B, self.seq, self.cfg.frontend_dim)).astype(np.float32)
            batch.pop("tokens")
        return batch

    def shard_for(self, batch: dict, sharding) -> dict:
        return {k: jax.device_put(v, sharding[k] if isinstance(sharding, dict)
                                  else sharding)
                for k, v in batch.items()}


def prefetching(source: SyntheticLM, start_step: int, sharding=None,
                depth: int = 2) -> Iterator[dict]:
    """Background-thread prefetch (the host-side MOB, if you like)."""
    q: queue.Queue = queue.Queue(maxsize=depth)
    stop = threading.Event()

    def worker():
        s = start_step
        while not stop.is_set():
            b = source.batch_at(s)
            if sharding is not None:
                b = source.shard_for(b, sharding)
            q.put(b)
            s += 1

    t = threading.Thread(target=worker, daemon=True)
    t.start()
    try:
        while True:
            yield q.get()
    finally:
        stop.set()
