"""AdamW (from scratch — no optax in this environment) with:

- linear-warmup + cosine-decay schedule
- global-norm gradient clipping
- decoupled weight decay
- optional 8-bit (int8 block-quantized) first/second moments, which shards
  the optimizer footprint of trillion-parameter configs (kimi-k2) to
  something a v5e pod can hold (see EXPERIMENTS.md §Dry-run).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.quant import QTensor, dequantize, quantize

F32 = jnp.float32


class AdamWConfig(NamedTuple):
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1
    moments_dtype: str = "f32"  # f32 | bf16 | int8


def schedule(cfg: AdamWConfig, step):
    step = step.astype(F32)
    warm = step / jnp.maximum(1.0, cfg.warmup_steps)
    prog = (step - cfg.warmup_steps) / jnp.maximum(
        1.0, cfg.total_steps - cfg.warmup_steps)
    prog = jnp.clip(prog, 0.0, 1.0)
    cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * jnp.where(step < cfg.warmup_steps, warm, cos)


def _encode_moment(x, kind: str):
    if kind == "int8":
        return quantize(x, axis=-1)
    if kind == "bf16":
        return x.astype(jnp.bfloat16)
    return x


def _decode_moment(x, kind: str):
    if kind == "int8":
        return dequantize(x)
    return x.astype(F32) if kind == "bf16" else x


def init_moments(params, cfg: AdamWConfig):
    def zeros_like(p):
        z = jnp.zeros(p.shape, F32)
        return _encode_moment(z, cfg.moments_dtype)
    mu = jax.tree.map(zeros_like, params)
    nu = jax.tree.map(zeros_like, params)
    return mu, nu


def moment_shapes(param_shapes, cfg: AdamWConfig):
    """ShapeDtypeStruct tree for the moments (dry-run)."""
    def conv(p):
        if cfg.moments_dtype == "int8":
            scale_shape = tuple(p.shape[:-1]) + (1,) if p.shape else ()
            return QTensor(jax.ShapeDtypeStruct(p.shape, jnp.int8),
                           jax.ShapeDtypeStruct(scale_shape or (1,), F32))
        dt = jnp.bfloat16 if cfg.moments_dtype == "bf16" else F32
        return jax.ShapeDtypeStruct(p.shape, dt)
    return jax.tree.map(conv, param_shapes), jax.tree.map(conv, param_shapes)


def global_norm(tree):
    leaves = [jnp.sum(jnp.square(x.astype(F32))) for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def adamw_update(cfg: AdamWConfig, params, grads, mu, nu, step):
    """Returns (new_params, new_mu, new_nu, metrics)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    lr = schedule(cfg, step)
    b1, b2 = cfg.b1, cfg.b2
    t = step.astype(F32) + 1.0
    bc1 = 1.0 - b1 ** t
    bc2 = 1.0 - b2 ** t
    md = cfg.moments_dtype

    def upd(p, g, m, v):
        gf = g.astype(F32) * scale
        mf = _decode_moment(m, md)
        vf = _decode_moment(v, md)
        mf = b1 * mf + (1 - b1) * gf
        vf = b2 * vf + (1 - b2) * gf * gf
        mhat = mf / bc1
        vhat = vf / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if p.ndim >= 2:  # decoupled weight decay on matrices only
            delta = delta + cfg.weight_decay * p.astype(F32)
        newp = (p.astype(F32) - lr * delta).astype(p.dtype)
        return newp, _encode_moment(mf, md), _encode_moment(vf, md)

    is_q = lambda x: isinstance(x, QTensor)
    flat_p, tdef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(mu, is_leaf=is_q)
    flat_v = jax.tree.leaves(nu, is_leaf=is_q)
    trip = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree.unflatten(tdef, [t[0] for t in trip])
    new_m = jax.tree.unflatten(tdef, [t[1] for t in trip])
    new_v = jax.tree.unflatten(tdef, [t[2] for t in trip])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_p, new_m, new_v, metrics
