"""Train state + step builders (pure functions; the launcher jits them with
shardings and donation)."""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import model as M
from repro.training.optimizer import AdamWConfig, adamw_update, init_moments, moment_shapes

F32 = jnp.float32


class TrainState(NamedTuple):
    step: jax.Array  # scalar int32
    params: Any
    mu: Any
    nu: Any


def init_state(cfg: ArchConfig, opt: AdamWConfig, rng) -> TrainState:
    params = M.init(cfg, rng)
    mu, nu = init_moments(params, opt)
    return TrainState(jnp.zeros((), jnp.int32), params, mu, nu)


def state_shapes(cfg: ArchConfig, opt: AdamWConfig,
                 main_repeats: int | None = None) -> TrainState:
    """ShapeDtypeStruct TrainState for dry-run lowering (no allocation)."""
    ps = M.param_shapes(cfg, main_repeats)
    mu, nu = moment_shapes(ps, opt)
    return TrainState(jax.ShapeDtypeStruct((), jnp.int32), ps, mu, nu)


def make_train_step(cfg: ArchConfig, opt: AdamWConfig, *, accum_steps: int = 1,
                    attn_chunk: int = 0, main_repeats: int | None = None,
                    compress_pod: bool = False, mesh=None):
    """Returns train_step(state, batch) -> (state, metrics).

    ``accum_steps`` > 1 runs gradient accumulation over microbatches (the
    batch's leading dim is split), trading step latency for activation
    memory — one of the §Perf levers.

    ``compress_pod`` replaces the implicit cross-pod gradient all-reduce
    with int8 all-gather + local int32 sum (error feedback omitted in the
    step variant; see training/compress.py) — ~4x fewer bytes on the slow
    pod-to-pod links.  Requires `mesh` with a "pod" axis; gradients are
    computed per-pod under shard_map (manual pod axis, auto data/model).
    """

    def loss_for(params, batch):
        return M.loss_fn(cfg, params, batch, attn_chunk=attn_chunk,
                         main_repeats=main_repeats)

    def grads_plain(params, batch):
        return jax.value_and_grad(loss_for, has_aux=True)(params, batch)

    if compress_pod and mesh is not None and "pod" in mesh.shape:
        from jax.sharding import PartitionSpec as P
        from repro.training.compress import compressed_tree_mean

        def per_pod(params, batch):
            (loss, extras), g = grads_plain(params, batch)
            g, _ = compressed_tree_mean(g, "pod")
            loss = jax.lax.pmean(loss, "pod")
            extras = jax.tree.map(lambda x: jax.lax.pmean(x.astype(jnp.float32),
                                                          "pod"), extras)
            return (loss, extras), g

        def grads_fn(params, batch):
            fn = jax.shard_map(per_pod, mesh=mesh, axis_names={"pod"},
                               in_specs=(P(), P("pod")),
                               out_specs=((P(), P()), P()),
                               check_vma=False)
            return fn(params, batch)
    else:
        grads_fn = grads_plain

    def train_step(state: TrainState, batch: dict):
        if accum_steps == 1:
            (loss, extras), grads = grads_fn(state.params, batch)
        else:
            def micro(b):
                split = jax.tree.map(
                    lambda x: x.reshape((accum_steps, -1) + x.shape[1:]), b)
                def body(carry, mb):
                    (l, e), g = jax.value_and_grad(loss_for, has_aux=True)(
                        state.params, mb)
                    acc, lsum = carry
                    acc = jax.tree.map(jnp.add, acc, g)
                    return (acc, lsum + l), e
                zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, F32), state.params)
                (g, lsum), es = jax.lax.scan(body, (zeros, jnp.zeros((), F32)), split)
                g = jax.tree.map(lambda x: x / accum_steps, g)
                e = jax.tree.map(lambda x: x[-1], es)
                return (lsum / accum_steps, e), g
            (loss, extras), grads = micro(batch)

        params, mu, nu, om = adamw_update(opt, state.params, grads,
                                          state.mu, state.nu, state.step)
        metrics = {"loss": loss, **extras, **om, "step": state.step}
        return TrainState(state.step + 1, params, mu, nu), metrics

    return train_step


def make_eval_step(cfg: ArchConfig, attn_chunk: int = 0):
    def eval_step(params, batch):
        loss, extras = M.loss_fn(cfg, params, batch, attn_chunk=attn_chunk)
        return {"loss": loss, **extras}
    return eval_step
