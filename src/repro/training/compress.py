"""Cross-pod int8 gradient compression (distributed-optimization trick).

The `pod` mesh axis is pure data parallelism over the (slow, DCN-class)
pod-to-pod links; compressing that all-reduce is the classic bandwidth
optimization.  We all-reduce in int8 with a shared (pmax'd) scale and int32
accumulation: for P pods, bytes-on-wire drop ~4x vs f32 (all-gather int8 +
local sum), with error feedback carrying the quantization residual to the
next step so convergence is unbiased to first order.

Used inside ``shard_map`` over the pod axis (see launch/train.py and the
§Perf collective hillclimb).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

F32 = jnp.float32


def compressed_mean(g, axis_name: str, err=None):
    """Mean of `g` across `axis_name` via int8 all-gather + local int32 sum.

    Returns (mean, new_err).  `err` (same shape as g) is the error-feedback
    residual; pass None to disable."""
    gf = g.astype(F32)
    if err is not None:
        gf = gf + err
    amax = lax.pmax(jnp.max(jnp.abs(gf)), axis_name)
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(gf / scale), -127, 127).astype(jnp.int8)
    from repro.core.torus import axis_size
    n = axis_size(axis_name)
    allq = lax.all_gather(q, axis_name)  # [n, ...] int8 on the wire
    mean = (jnp.sum(allq.astype(jnp.int32), axis=0).astype(F32) * scale) / n
    new_err = gf - q.astype(F32) * scale if err is not None else None
    return mean.astype(g.dtype), new_err


def compressed_tree_mean(grads, axis_name: str, errs=None):
    """Tree-mapped compressed_mean; errs may be None (no error feedback)."""
    if errs is None:
        return jax.tree.map(lambda g: compressed_mean(g, axis_name)[0], grads), None
    pairs = jax.tree.map(lambda g, e: compressed_mean(g, axis_name, e),
                         grads, errs)
    mean = jax.tree.map(lambda p: p[0], pairs, is_leaf=lambda x: isinstance(x, tuple))
    err = jax.tree.map(lambda p: p[1], pairs, is_leaf=lambda x: isinstance(x, tuple))
    return mean, err
