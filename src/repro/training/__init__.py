from repro.training.optimizer import AdamWConfig, adamw_update, init_moments, schedule  # noqa: F401
from repro.training.step import TrainState, init_state, make_eval_step, make_train_step, state_shapes  # noqa: F401
