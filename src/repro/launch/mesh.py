"""Production meshes.

Defined as functions (never module-level constants) so importing this module
never touches jax device state; the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` *before* any jax
import to build these meshes on CPU.

Topology note: a v5e pod's ICI is a physical 2-D torus; ``jax.make_mesh``
orders devices so that neighboring mesh coordinates are ICI neighbors —
which is exactly what the paper's switchless-torus schedules
(``repro.core.torus``) assume.
"""
from __future__ import annotations

import math

import numpy as np

import jax

try:  # older jax releases have no AxisType / axis_types kwarg
    from jax.sharding import AxisType
except ImportError:
    AxisType = None


def make_production_mesh(*, multi_pod: bool = False, shape=None, axes=None):
    """The pod-scale mesh — validated against the platform's actual device
    count instead of assuming a 256-chip pod.  Pass ``shape=``/``axes=`` for
    a small dev mesh (e.g. ``shape=(1, 8)`` on a forced-8-device CPU)."""
    if shape is None:
        shape = (2, 16, 16) if multi_pod else (16, 16)
        axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    elif axes is None:
        axes = ("pod", "data", "model")[-len(tuple(shape)):]
    n = math.prod(shape)
    avail = jax.device_count()
    if n != avail:
        raise ValueError(
            f"mesh shape {tuple(shape)} needs {n} devices but the platform "
            f"has {avail}; pass shape=/axes= matching the device count "
            f"(e.g. shape=(1, {avail})), or use make_device_mesh to take a "
            f"submesh of the available devices")
    return make_mesh(shape, axes)


def make_device_mesh(shape, axes, devices=None):
    """Mesh over the *first* ``prod(shape)`` devices.

    Unlike ``jax.make_mesh`` this does not require using every device on the
    platform — the serving engine's ``MeshSpec`` builds small dev meshes
    ((1, 2), (1, 4), ...) on a forced-8-device CPU this way."""
    devices = list(jax.devices() if devices is None else devices)
    n = math.prod(shape)
    if len(devices) < n:
        raise ValueError(f"mesh shape {tuple(shape)} needs {n} devices; "
                         f"only {len(devices)} available")
    arr = np.asarray(devices[:n]).reshape(tuple(shape))
    if AxisType is None:
        return jax.sharding.Mesh(arr, tuple(axes))
    try:
        return jax.sharding.Mesh(arr, tuple(axes),
                                 axis_types=(AxisType.Auto,) * len(axes))
    except TypeError:  # Mesh without the axis_types kwarg
        return jax.sharding.Mesh(arr, tuple(axes))


def make_mesh(shape, axes):
    if AxisType is None:
        return jax.make_mesh(tuple(shape), tuple(axes))
    return jax.make_mesh(tuple(shape), tuple(axes),
                         axis_types=(AxisType.Auto,) * len(axes))


def data_axes(mesh) -> tuple[str, ...]:
    """Axes used for batch/data parallelism, pod-major."""
    return tuple(a for a in ("pod", "data") if a in mesh.shape)


def host_mesh(n: int = 1, model: int = 1):
    """Small local mesh for examples/tests on real CPU devices."""
    return make_mesh((n, model), ("data", "model"))
