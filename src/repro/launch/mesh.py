"""Production meshes.

Defined as functions (never module-level constants) so importing this module
never touches jax device state; the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` *before* any jax
import to build these meshes on CPU.

Topology note: a v5e pod's ICI is a physical 2-D torus; ``jax.make_mesh``
orders devices so that neighboring mesh coordinates are ICI neighbors —
which is exactly what the paper's switchless-torus schedules
(``repro.core.torus``) assume.
"""
from __future__ import annotations

import jax

try:  # older jax releases have no AxisType / axis_types kwarg
    from jax.sharding import AxisType
except ImportError:
    AxisType = None


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_mesh(shape, axes):
    if AxisType is None:
        return jax.make_mesh(tuple(shape), tuple(axes))
    return jax.make_mesh(tuple(shape), tuple(axes),
                         axis_types=(AxisType.Auto,) * len(axes))


def data_axes(mesh) -> tuple[str, ...]:
    """Axes used for batch/data parallelism, pod-major."""
    return tuple(a for a in ("pod", "data") if a in mesh.shape)


def host_mesh(n: int = 1, model: int = 1):
    """Small local mesh for examples/tests on real CPU devices."""
    return make_mesh((n, model), ("data", "model"))
