import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512").strip()

"""Multi-pod dry-run: lower + compile every (arch x shape) cell on the
single-pod (16 data x 16 model = 256 chips) and multi-pod (2 pods = 512
chips) production meshes, record memory_analysis / cost_analysis /
collective schedule, and derive roofline terms.

The two XLA_FLAGS lines above MUST stay the first statements in this module
(before any jax import) — jax locks the device count on first init.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun                    # all cells
    PYTHONPATH=src python -m repro.launch.dryrun --arch deepseek-67b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --mesh multi       # 512-chip pass
    ... --set remat_policy=dots --set use_torus_tp=1 --tag mytag    # perf knobs

Results land in out/dryrun/<mesh>/<arch>--<shape>[--tag].json and are
aggregated into EXPERIMENTS.md tables by benchmarks/roofline_table.py.
"""
import argparse
import json
import time
import traceback

import jax
import jax.numpy as jnp

from repro.configs import ASSIGNED, SHAPES, cell_skip_reason, get_config
from repro.launch import roofline as RL
from repro.launch.cells import build_cell
from repro.launch.mesh import make_production_mesh
from repro.launch.sharding import activation_mesh
from repro.training.optimizer import AdamWConfig

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..", "out", "dryrun")


def _compile(cell, mesh):
    # in_shardings are NamedShardings (mesh attached) — no ambient mesh needed
    jitted = jax.jit(cell.fn, in_shardings=cell.in_shardings,
                     donate_argnums=cell.donate)
    from repro.launch.sharding import profile_for
    t0 = time.time()
    with activation_mesh(mesh, profile_for(cell.cfg)):  # trace-time constraints
        lowered = jitted.lower(*cell.args)
    compiled = lowered.compile()
    dt = time.time() - t0
    return lowered, compiled, dt


def run_cell(arch: str, shape_name: str, mesh, mesh_name: str, *,
             overrides: dict, opt: AdamWConfig, do_roofline: bool,
             tag: str = "") -> dict:
    cfg = get_config(arch)
    overrides = dict(overrides or {})
    accum = int(overrides.pop("accum_steps", 1))
    compress_pod = bool(overrides.pop("compress_pod", False))
    if overrides.pop("f32", False):  # CPU-XLA: 16-bit ops inside manual
        cfg = cfg.with_(param_dtype=jnp.float32,  # regions trip a promotion-
                        compute_dtype=jnp.float32)  # pass abort; TPU is fine
    if overrides:
        cfg = cfg.with_(**overrides)
    shape = SHAPES[shape_name]
    skip = cell_skip_reason(cfg, shape)
    rec: dict = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
                 "chips": mesh.size, "overrides": overrides, "tag": tag}
    if skip:
        rec["skipped"] = skip
        return rec

    # --- full-depth compile: proves sharding + gives memory analysis.
    # Query-chunked attention bounds the transient score tensors (the jnp
    # analogue of the Pallas flash kernel's VMEM blocking); identical math,
    # so the cost compiles below (which must stay scan-free) use chunk=0.
    chunk = 0 if shape.step == "decode" else min(2048, shape.seq_len // 2)
    cell = build_cell(cfg, shape, mesh, opt=opt, attn_chunk=chunk,
                      accum_steps=accum, compress_pod=compress_pod)
    lowered, compiled, dt = _compile(cell, mesh)
    ma = compiled.memory_analysis()
    rec["compile_s"] = round(dt, 1)
    rec["memory"] = {
        "argument_bytes": int(ma.argument_size_in_bytes),
        "output_bytes": int(ma.output_size_in_bytes),
        "temp_bytes": int(ma.temp_size_in_bytes),
        "alias_bytes": int(ma.alias_size_in_bytes),
        "peak_per_device_gib": round(
            (ma.argument_size_in_bytes + ma.output_size_in_bytes
             + ma.temp_size_in_bytes - ma.alias_size_in_bytes) / 2**30, 3),
    }
    full_coll = RL.collective_bytes(compiled.as_text())
    rec["scan_hlo_collectives"] = {k: v for k, v in full_coll.items()
                                   if k != "counts"}

    if do_roofline:
        # --- cost compiles: unrolled main stage at depths 1 and 2
        stages = cell.cfg.stages()
        main = max(range(len(stages)), key=lambda i: stages[i].repeats)
        repeats = stages[main].repeats
        from repro.models.layers import ATTN_STUB
        costs, colls, stub_bytes = [], [], []
        for r in (1, 2):
            c = build_cell(cfg, shape, mesh, opt=opt, main_repeats=r,
                           scan_layers=False, attn_chunk=0)
            lw, cp, _ = _compile(c, mesh)
            costs.append(cp.cost_analysis())
            colls.append(RL.collective_bytes(cp.as_text()))
            # flash-adjusted memory: same model with the attention core
            # replaced by a qkvo-traffic stand-in (the Pallas kernel's HBM
            # footprint); its "bytes accessed" IS the adjusted term.
            # Fresh build_cell -> fresh closures, so the jit cache can't
            # serve the non-stub trace.
            tok = ATTN_STUB.set(True)
            try:
                c2 = build_cell(cfg, shape, mesh, opt=opt, main_repeats=r,
                                scan_layers=False, attn_chunk=0)
                _, cps, _ = _compile(c2, mesh)
            finally:
                ATTN_STUB.reset(tok)
            stub_bytes.append(cps.cost_analysis().get("bytes accessed", 0.0))
        attn1 = max(costs[0].get("bytes accessed", 0.0) - stub_bytes[0], 0.0)
        attn2 = max(costs[1].get("bytes accessed", 0.0) - stub_bytes[1], 0.0)
        terms = RL.terms_from_pair(costs[0], costs[1], colls[0], colls[1],
                                   repeats, attn1, attn2)
        mf = RL.model_flops(cell.cfg, shape)
        rec["roofline"] = terms.as_dict()
        rec["roofline"]["model_flops_total"] = mf
        rec["roofline"]["model_flops_per_chip"] = mf / mesh.size
        rec["roofline"]["useful_ratio"] = (mf / mesh.size) / max(terms.flops, 1.0)
        rec["roofline"]["t_bound_overlap_s"] = terms.t_bound_overlap
        rec["roofline"]["t_bound_serial_s"] = terms.t_bound_serial
        rec["roofline"]["roofline_fraction"] = (
            (mf / mesh.size / RL.PEAK_FLOPS) / max(terms.t_bound_overlap, 1e-30))
        rec["roofline"]["roofline_fraction_flash"] = (
            (mf / mesh.size / RL.PEAK_FLOPS)
            / max(terms.t_bound_overlap_flash, 1e-30))
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, help="one arch id (default: all)")
    ap.add_argument("--shape", default=None, help="one shape (default: all)")
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--no-roofline", action="store_true")
    ap.add_argument("--set", dest="sets", action="append", default=[],
                    help="config override key=value (repeatable)")
    ap.add_argument("--moments", default="f32", choices=["f32", "bf16", "int8"])
    ap.add_argument("--tag", default="", help="suffix for the output json")
    ap.add_argument("--force", action="store_true", help="recompute existing")
    args = ap.parse_args()

    overrides = {}
    for kv in args.sets:
        k, v = kv.split("=", 1)
        overrides[k] = (v if not v.lstrip("-").isdigit() else int(v)) \
            if v not in ("True", "False") else v == "True"

    archs = [args.arch] if args.arch else ASSIGNED
    shapes = [args.shape] if args.shape else list(SHAPES)
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]
    opt = AdamWConfig(moments_dtype=args.moments)

    os.makedirs(OUT_DIR, exist_ok=True)
    failures = []
    for multi in meshes:
        mesh = make_production_mesh(multi_pod=multi)
        mname = "pod2x16x16" if multi else "pod16x16"
        mdir = os.path.join(OUT_DIR, mname)
        os.makedirs(mdir, exist_ok=True)
        for arch in archs:
            for shape in shapes:
                suffix = f"--{args.tag}" if args.tag else ""
                fn = os.path.join(mdir, f"{arch}--{shape}{suffix}.json")
                if os.path.exists(fn) and not args.force:
                    print(f"[skip existing] {mname} {arch} {shape}")
                    continue
                t0 = time.time()
                try:
                    rec = run_cell(arch, shape, mesh, mname,
                                   overrides=overrides, opt=opt,
                                   do_roofline=(not args.no_roofline and not multi),
                                   tag=args.tag)
                except Exception as e:  # a cell failure is a bug: record it
                    rec = {"arch": arch, "shape": shape, "mesh": mname,
                           "error": f"{type(e).__name__}: {e}",
                           "trace": traceback.format_exc()[-2000:]}
                    failures.append((mname, arch, shape, str(e)[:120]))
                with open(fn, "w") as f:
                    json.dump(rec, f, indent=1, default=float)
                status = ("SKIP " + rec["skipped"][:40] if "skipped" in rec
                          else "ERROR " + rec["error"][:60] if "error" in rec
                          else f"ok mem={rec['memory']['peak_per_device_gib']}GiB")
                print(f"[{time.time()-t0:6.1f}s] {mname} {arch:22s} {shape:12s} {status}",
                      flush=True)
    if failures:
        print(f"\n{len(failures)} FAILURES:")
        for f_ in failures:
            print("  ", *f_)
        raise SystemExit(1)
    print("\nDRY-RUN PASS")


if __name__ == "__main__":
    main()
