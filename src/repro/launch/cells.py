"""Dry-run cell construction: (arch x shape x mesh) -> lowerable jit call.

A *cell* is one entry of the assigned 10 x 4 grid.  ``build_cell`` returns
the step function, ShapeDtypeStruct arguments (zero allocation — kimi-k2's
1T parameters stay imaginary) and the full in_shardings tree resolved from
the logical-axis rules.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig, ShapeConfig
from repro.launch import sharding as SH
from repro.models import model as M
from repro.models.params import ParamSpec, shape_tree
from repro.training.optimizer import AdamWConfig
from repro.training.step import make_train_step, state_shapes


def prepare_arch(cfg: ArchConfig, mesh: Mesh) -> ArchConfig:
    """Specialize an arch config for a mesh: head padding for TP
    divisibility, MoE dispatch groups = DP degree."""
    tp = mesh.shape.get("model", 1)
    dp = 1
    for a in ("pod", "data"):
        dp *= mesh.shape.get(a, 1)
    kw: dict = {"num_moe_groups": dp}
    if cfg.num_heads:
        kw["pad_heads_to"] = tp  # shard q-heads over the model axis
    new = cfg.with_(**kw)
    if (not new.use_mla) and new.num_heads and new.num_kv_heads \
            and new.padded_heads % new.num_kv_heads:
        new = new.with_(pad_heads_to=1)  # keep GQA grouping exact; replicate
    return new


def input_specs(cfg: ArchConfig, shape: ShapeConfig) -> dict:
    """ShapeDtypeStruct stand-ins (as ParamSpecs for axis metadata) for every
    model input of this cell."""
    B, S = shape.global_batch, shape.seq_len
    sp: dict = {}
    if shape.step == "decode":
        sp["tokens"] = ParamSpec((B, 1), ("batch", None), dtype=jnp.int32)
    elif cfg.audio_frontend:
        sp["frames"] = ParamSpec((B, S, cfg.frontend_dim), ("batch", None, None),
                                 dtype=cfg.compute_dtype)
    else:
        sp["tokens"] = ParamSpec((B, S), ("batch", None), dtype=jnp.int32)
    if shape.step == "train":
        sp["labels"] = ParamSpec((B, S), ("batch", None), dtype=jnp.int32)
    if cfg.vision_tokens and shape.step != "decode":
        sp["images"] = ParamSpec((B, cfg.vision_tokens, cfg.vision_dim),
                                 ("batch", None, None), dtype=cfg.compute_dtype)
    return sp


class Cell(NamedTuple):
    fn: Any  # callable to jit
    args: tuple  # ShapeDtypeStruct pytrees
    in_shardings: tuple
    donate: tuple
    cfg: ArchConfig


def _params_shardings(cfg, mesh, main_repeats):
    specs = M.param_specs(cfg, main_repeats)
    return SH.tree_pspecs(specs, mesh, fsdp=cfg.fsdp,
                          profile=SH.profile_for(cfg))


def _state_shardings(cfg, opt, mesh, main_repeats):
    from repro.training.step import TrainState
    p_ns = _params_shardings(cfg, mesh, main_repeats)
    if opt.moments_dtype == "int8":
        mom = jax.tree.map(SH.qtensor_pspecs, p_ns)
    else:
        mom = p_ns
    return TrainState(SH.replicated(mesh), p_ns, mom, mom)


def _batch_shardings(cfg, shape, mesh):
    sp = input_specs(cfg, shape)
    return SH.tree_pspecs(sp, mesh, fsdp=False, profile=SH.profile_for(cfg))


def _batch_shapes(cfg, shape):
    return shape_tree(input_specs(cfg, shape), cfg.compute_dtype)


def build_cell(cfg0: ArchConfig, shape: ShapeConfig, mesh: Mesh, *,
               opt: AdamWConfig | None = None,
               main_repeats: int | None = None,
               scan_layers: bool = True,
               attn_chunk: int = 0,
               accum_steps: int = 1,
               compress_pod: bool = False) -> Cell:
    opt = opt or AdamWConfig()
    cfg = prepare_arch(cfg0, mesh).with_(scan_layers=scan_layers)
    B, S = shape.global_batch, shape.seq_len

    if shape.step == "train":
        step = make_train_step(cfg, opt, attn_chunk=attn_chunk,
                               accum_steps=accum_steps,
                               main_repeats=main_repeats,
                               compress_pod=compress_pod, mesh=mesh)
        st = state_shapes(cfg, opt, main_repeats)
        bt = _batch_shapes(cfg, shape)
        in_sh = (_state_shardings(cfg, opt, mesh, main_repeats),
                 _batch_shardings(cfg, shape, mesh))
        return Cell(step, (st, bt), in_sh, (0,), cfg)

    if shape.step == "prefill":
        def fn(params, batch):
            return M.prefill(cfg, params, batch, attn_chunk=attn_chunk,
                             main_repeats=main_repeats)
        ps = M.param_shapes(cfg, main_repeats)
        bt = _batch_shapes(cfg, shape)
        in_sh = (_params_shardings(cfg, mesh, main_repeats),
                 _batch_shardings(cfg, shape, mesh))
        return Cell(fn, (ps, bt), in_sh, (), cfg)

    # decode
    def fn(params, caches, token, pos):
        return M.decode_step(cfg, params, caches, token, pos,
                             main_repeats=main_repeats)
    ps = M.param_shapes(cfg, main_repeats)
    cs_specs = M.cache_specs(cfg, B, S, main_repeats)
    cs = shape_tree(cs_specs, cfg.compute_dtype)
    tok = jax.ShapeDtypeStruct((B, 1), jnp.int32)
    pos = jax.ShapeDtypeStruct((), jnp.int32)
    in_sh = (_params_shardings(cfg, mesh, main_repeats),
             SH.tree_pspecs(cs_specs, mesh, fsdp=False,
                            profile=SH.profile_for(cfg)),
             NamedSharding(mesh, SH.batch_pspec(mesh) if B > 1 else P()),
             SH.replicated(mesh))
    # token sharding: batch axis must divide B
    dp = 1
    for a in ("pod", "data"):
        dp *= mesh.shape.get(a, 1)
    if B % dp:
        in_sh = (in_sh[0], in_sh[1], SH.replicated(mesh), in_sh[3])
    return Cell(fn, (ps, cs, tok, pos), in_sh, (1,), cfg)
