"""Production training driver.

On a real pod this is the per-host entrypoint (jax.distributed.initialize +
the production mesh); on this container it runs the same code path on a
local mesh with a reduced config — the end-to-end train driver:

    PYTHONPATH=src python -m repro.launch.train --arch olmo-1b --steps 200 \
        --batch 8 --seq 128 --reduced

Features: mesh + logical-axis sharding, donated jit train step, deterministic
sharded data pipeline with prefetch, checkpoint-every-N + auto-resume,
straggler monitor, gradient accumulation, optional int8 optimizer moments.
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.configs import get_config, reduce_config
from repro.data.pipeline import SyntheticLM
from repro.launch import sharding as SH
from repro.launch.cells import prepare_arch
from repro.launch.mesh import make_mesh
from repro.models import model as M
from repro.runtime import StragglerMonitor, TrainRunner
from repro.training import AdamWConfig, init_state, make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="olmo-1b")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--moments", default="f32", choices=["f32", "bf16", "int8"])
    ap.add_argument("--reduced", action="store_true",
                    help="shrink the arch for single-host runs")
    ap.add_argument("--mesh", default="1x1", help="data x model, e.g. 4x2")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    dp, tp = (int(v) for v in args.mesh.split("x"))
    mesh = make_mesh((dp, tp), ("data", "model"))

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduce_config(cfg)
    cfg = prepare_arch(cfg, mesh)
    opt = AdamWConfig(lr=args.lr, warmup_steps=max(5, args.steps // 20),
                      total_steps=args.steps, moments_dtype=args.moments)

    state = init_state(cfg, opt, jax.random.PRNGKey(0))
    n = sum(x.size for x in jax.tree.leaves(state.params))
    print(f"arch={cfg.name} params={n:,} mesh={dict(mesh.shape)} "
          f"accum={args.accum} moments={args.moments}")

    # shard state onto the mesh per the logical-axis rules
    if mesh.size > 1:
        st_sh = SH.tree_pspecs(M.param_specs(cfg), mesh, fsdp=cfg.fsdp)
        state = state._replace(
            params=jax.tree.map(jax.device_put, state.params, st_sh))

    raw_step = make_train_step(cfg, opt, accum_steps=args.accum)
    with SH.activation_mesh(mesh):
        step = jax.jit(raw_step, donate_argnums=0)

        data = SyntheticLM(cfg, batch=args.batch, seq=args.seq)
        mgr = CheckpointManager(args.ckpt_dir, keep_n=3)
        mon = StragglerMonitor()

        losses = []
        t_start = time.time()

        def logged_step(st, batch):
            st, m = step(st, batch)
            s = int(m["step"])
            losses.append(float(m["loss"]))
            if (s + 1) % args.log_every == 0:
                tput = args.batch * args.seq * args.log_every / (
                    time.time() - logged_step.t0)
                logged_step.t0 = time.time()
                print(f"step {s+1:5d} loss {np.mean(losses[-args.log_every:]):.4f} "
                      f"lr {float(m['lr']):.2e} gnorm {float(m['grad_norm']):.2f} "
                      f"{tput:.0f} tok/s", flush=True)
            return st, m

        logged_step.t0 = time.time()
        runner = TrainRunner(logged_step, data.batch_at, mgr,
                             ckpt_every=args.ckpt_every, monitor=mon)
        state, report = runner.run(state, args.steps)
    dt = time.time() - t_start
    print(f"done: {report.final_step} steps in {dt:.0f}s, "
          f"restarts={report.restarts}, stragglers={report.straggler_flags}, "
          f"loss {report.losses[0]:.3f} -> {np.mean(report.losses[-10:]):.3f}")


if __name__ == "__main__":
    main()
