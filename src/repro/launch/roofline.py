"""Roofline-term derivation from compiled dry-run artifacts.

Three terms per (arch x shape x mesh), in seconds:

    compute    = HLO_FLOPs        / (chips * PEAK_FLOPS)
    memory     = HLO_bytes        / (chips * HBM_BW)
    collective = collective_bytes / (chips * LINK_BW)

HLO_FLOPs / HLO_bytes come from ``compiled.cost_analysis()`` on *unrolled*
cost compiles at main-stage depths 1 and 2, linearly extrapolated to full
depth (XLA counts a scan body once, so the production scan compile cannot be
used for costs — see DESIGN.md §5).  collective_bytes is parsed from the
optimized HLO text with op-specific wire-byte factors.

Hardware constants: TPU v5e-class — 197 bf16 TFLOP/s, 819 GB/s HBM,
~50 GB/s/link ICI (1 link assumed per transfer; conservative).
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field

PEAK_FLOPS = 197e12  # bf16 per chip
HBM_BW = 819e9  # bytes/s per chip
LINK_BW = 50e9  # bytes/s per ICI link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
}

_COLL_RE = re.compile(
    r"=\s*(.+?)\s"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(-start)?\(")

_SHAPE_RE = re.compile(r"(pred|bf16|f16|f32|f64|s8|u8|s16|u16|s32|u32|s64|u64|c64)\[([\d,]*)\]")


def _shape_bytes(txt: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(txt):
        nb = _DTYPE_BYTES.get(dt, 4)
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * nb
    return total


# wire-byte multiplier on the *output* shape, ring-algorithm estimates:
#   all-gather      out ~ gathered size; each device receives (n-1)/n out ~ out
#   all-reduce      ring RS+AG moves ~2x the buffer
#   reduce-scatter  input is n x output; each device moves ~ n x out ~ in
#   all-to-all      each device sends/receives (n-1)/n of the buffer ~ out
#   collective-permute  one neighbor hop, exactly out bytes
_FACTORS = {"all-gather": 1.0, "all-reduce": 2.0, "reduce-scatter": 1.0,
            "all-to-all": 1.0, "collective-permute": 1.0}


def collective_bytes(hlo_text: str) -> dict:
    """Per-device wire bytes by collective kind, from optimized HLO text.
    reduce-scatter is scaled by its group size (parsed where possible)."""
    out = {k: 0.0 for k in _FACTORS}
    counts = {k: 0 for k in _FACTORS}
    cross_pod = 0.0  # collectives whose replica groups have size 2 = pod axis
    for line in hlo_text.splitlines():
        if "-done(" in line:
            continue  # async completion: bytes counted at the -start op
        m = _COLL_RE.search(line)
        if not m:
            continue
        kind = m.group(2)
        out_bytes = _shape_bytes(m.group(1))  # output type(s) on the lhs
        if m.group(3):  # -start returns an (input, output, ...) tuple alias
            out_bytes /= 2
        factor = _FACTORS[kind]
        gsize = _group_size(line)
        if kind == "reduce-scatter":
            factor = max(1.0, gsize - 1.0)
        out[kind] += out_bytes * factor
        counts[kind] += 1
        if gsize == 2:  # pod-axis (DCN-class links) traffic, tracked apart
            cross_pod += out_bytes * factor
    out["total"] = sum(v for k, v in out.items() if k in _FACTORS)
    out["cross_pod"] = cross_pod
    out["counts"] = counts
    return out


def scope_output_bytes(hlo_text: str, scope: str = "attn_core") -> float:
    """~2x output bytes of every op inside `scope` (named_scope metadata).

    Used for the flash-adjusted memory term: the attention core runs as the
    validated Pallas flash kernel on the TPU target, whose score tensors
    never leave VMEM; the reference-jnp HLO materializes them per op.  2x
    output (one read + one write) per op is a *conservative* (under-)
    estimate of what cost_analysis charged, so the adjusted term stays an
    upper bound."""
    total = 0.0
    for line in hlo_text.splitlines():
        if scope not in line:
            continue
        eq = line.find("=")
        if eq < 0:
            continue
        m = _SHAPE_RE.search(line, eq)
        if m:
            total += 2 * _shape_bytes(m.group(0))
    return total


def _group_size(line: str) -> float:
    m = re.search(r"replica_groups=\{\{([\d,]+)\}", line)
    if m:
        return len(m.group(1).split(","))
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]", line)
    if m:  # iota form [ngroups, group_size]
        return int(m.group(2))
    return 16.0  # mesh model-axis default


@dataclass
class RooflineTerms:
    flops: float = 0.0  # per-device HLO flops
    bytes: float = 0.0  # per-device HBM bytes accessed
    coll_bytes: float = 0.0  # per-device wire bytes
    attn_core_bytes: float = 0.0  # reference-attention HBM traffic that the
    # Pallas flash kernel keeps in VMEM on the TPU target
    coll_detail: dict = field(default_factory=dict)

    @property
    def t_compute(self) -> float:
        return self.flops / PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        return self.bytes / HBM_BW

    @property
    def t_memory_flash(self) -> float:
        """Memory term with the attention core costed as the flash kernel."""
        return max(self.bytes - self.attn_core_bytes, 0.0) / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.coll_bytes / LINK_BW

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def t_bound_serial(self) -> float:
        return self.t_compute + self.t_memory + self.t_collective

    @property
    def t_bound_overlap(self) -> float:
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def t_bound_overlap_flash(self) -> float:
        return max(self.t_compute, self.t_memory_flash, self.t_collective)

    def as_dict(self) -> dict:
        return {
            "flops": self.flops, "bytes": self.bytes,
            "coll_bytes": self.coll_bytes,
            "attn_core_bytes": self.attn_core_bytes,
            "t_compute_s": self.t_compute, "t_memory_s": self.t_memory,
            "t_memory_flash_s": self.t_memory_flash,
            "t_collective_s": self.t_collective,
            "bottleneck": self.bottleneck,
            "coll_detail": self.coll_detail,
        }


def extrapolate(v1: float, v2: float, repeats: int) -> float:
    """Linear depth extrapolation from main-stage repeats 1 and 2."""
    return v1 + (v2 - v1) * (repeats - 1)


def terms_from_pair(cost1: dict, cost2: dict, coll1: dict, coll2: dict,
                    repeats: int, attn1: float = 0.0,
                    attn2: float = 0.0) -> RooflineTerms:
    fl = extrapolate(cost1.get("flops", 0.0), cost2.get("flops", 0.0), repeats)
    by = extrapolate(cost1.get("bytes accessed", 0.0),
                     cost2.get("bytes accessed", 0.0), repeats)
    cb = extrapolate(coll1["total"], coll2["total"], repeats)
    ab = extrapolate(attn1, attn2, repeats)
    detail = {k: extrapolate(coll1[k], coll2[k], repeats)
              for k in _FACTORS}
    return RooflineTerms(flops=fl, bytes=by, coll_bytes=cb,
                         attn_core_bytes=ab, coll_detail=detail)


# ---------------------------------------------------------------------------
# MODEL_FLOPS (analytic "useful work") per config
# ---------------------------------------------------------------------------

def active_params(cfg) -> tuple[int, int]:
    """(total, active-per-token) parameter counts, from the param specs."""
    from repro.models.model import param_specs
    from repro.models.params import is_spec
    import math

    import jax
    total = active = 0
    for path, s in jax.tree_util.tree_flatten_with_path(
            param_specs(cfg), is_leaf=is_spec)[0]:
        n = math.prod(s.shape)
        total += n
        if "experts" in str(s.axes) and "ffn" in str(s.axes):
            active += n * cfg.experts_per_token / max(1, cfg.num_experts)
        elif "vocab" in str(s.axes):
            active += n  # embed+head counted once (gather is cheap but the
            # head GEMM is real; keep both for a conservative ratio)
        else:
            active += n
    return int(total), int(active)


def model_flops(cfg, shape) -> float:
    """6*N_active*tokens (train) / 2*N_active*tokens (inference)."""
    _, act = active_params(cfg)
    toks = shape.global_batch * (shape.seq_len if shape.step != "decode" else 1)
    mult = 6 if shape.step == "train" else 2
    return float(mult * act * toks)
