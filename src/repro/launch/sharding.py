"""Logical-axis -> mesh partitioning rules (MaxText/t5x style).

Every parameter / activation / cache dimension carries a logical axis name
(see ``repro.models.params``); this module maps those onto the production
mesh with divisibility-checked fallback (a 16-way model axis cannot shard 8
KV heads -> replicate) and FSDP (ZeRO-3) sharding of params/optimizer over
the data axis.
"""
from __future__ import annotations

import contextlib
import contextvars

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.quant import QTensor

# NOTE: repro.models.params is imported lazily inside functions — model code
# imports `constrain` from this module, so a module-level import here would
# be circular.

# tensor-parallel rules: logical axis -> mesh axis
TP_RULES: dict[str, str] = {
    "vocab": "model",
    "ffn": "model",
    "heads": "model",
    "kv_heads": "model",
    "experts": "model",
}
# data-parallel rules for activations/inputs (pod-major batch)
BATCH_AXES = ("pod", "data")
# FSDP preference order: which logical axis to shard over `data`
FSDP_PREF = ("embed", "ffn", "vocab", "frontend", "lora", "qk")


import dataclasses as _dc


@_dc.dataclass(frozen=True)
class ShardingProfile:
    """Parallelism layout.  "2d" = TP over `model` + FSDP over `data`
    (default); "fsdp" = no tensor parallelism, batch and parameters sharded
    over BOTH axes (ZeRO-3 across all 256 chips) — the right layout when the
    per-chip batch stays >= 1 and TP's residual all-reduces dominate
    (see EXPERIMENTS.md §Perf I5)."""
    tp_rules: dict = _dc.field(default_factory=lambda: dict(TP_RULES))
    batch_axes: tuple = BATCH_AXES
    fsdp_axes: tuple = ("data",)


def profile_for(cfg) -> ShardingProfile:
    if getattr(cfg, "parallel_mode", "2d") == "fsdp":
        return ShardingProfile(tp_rules={},
                               batch_axes=("pod", "data", "model"),
                               fsdp_axes=("data", "model"))
    return ShardingProfile()


def _divisible(dim: int, size: int) -> bool:
    return dim % size == 0 and dim >= size


def resolve_pspec(spec, mesh: Mesh, *, fsdp: bool = False,
                  extra_rules: dict | None = None,
                  profile: "ShardingProfile | None" = None) -> P:
    profile = profile or _current_profile()
    rules = dict(profile.tp_rules)
    if extra_rules:
        rules.update(extra_rules)
    assigned: list = []
    used: set = set()
    for dim, ax in zip(spec.shape, spec.axes):
        entry = None
        if ax == "batch":
            # graded fallback: full batch axes, then drop leading axes
            bax = tuple(a for a in profile.batch_axes if a in mesh.shape)
            cands = [bax[i:] for i in range(len(bax))]
            for cand in cands:
                size = 1
                for a in cand:
                    size *= mesh.shape[a]
                if cand and not (used & set(cand)) and _divisible(dim, size):
                    entry = cand if len(cand) > 1 else cand[0]
                    used |= set(cand)
                    break
        elif ax in rules:
            m = rules[ax]
            if m and m in mesh.shape and m not in used and _divisible(dim, mesh.shape[m]):
                entry = m
                used.add(m)
        assigned.append(entry)
    fax = tuple(a for a in profile.fsdp_axes if a in mesh.shape and a not in used)
    if fsdp and fax:
        fsize = 1
        for a in fax:
            fsize *= mesh.shape[a]
        # prefer the canonical FSDP axes, then any unassigned divisible dim
        order = sorted(
            range(len(assigned)),
            key=lambda i: (FSDP_PREF.index(spec.axes[i])
                           if spec.axes[i] in FSDP_PREF else len(FSDP_PREF)),
        )
        for i in order:
            if assigned[i] is None and spec.axes[i] is not None \
                    and _divisible(spec.shape[i], fsize):
                assigned[i] = fax if len(fax) > 1 else fax[0]
                break
    return P(*assigned)


def tree_pspecs(spec_tree, mesh: Mesh, *, fsdp: bool = False,
                extra_rules: dict | None = None,
                profile: "ShardingProfile | None" = None):
    from repro.models.params import is_spec
    return jax.tree.map(
        lambda s: NamedSharding(mesh, resolve_pspec(s, mesh, fsdp=fsdp,
                                                    extra_rules=extra_rules,
                                                    profile=profile)),
        spec_tree, is_leaf=is_spec)


def moment_pspecs(param_pspec_tree):
    """Moments mirror param shardings; int8 QTensor scales drop the last dim."""
    def conv(ns: NamedSharding):
        return ns
    return jax.tree.map(conv, param_pspec_tree)


def qtensor_pspecs(param_ns: NamedSharding) -> QTensor:
    spec = param_ns.spec
    scale_spec = P(*(tuple(spec[:-1]) + (None,))) if len(spec) else P()
    return QTensor(param_ns, NamedSharding(param_ns.mesh, scale_spec))


def replicated(mesh: Mesh):
    return NamedSharding(mesh, P())


def tp_size(mesh: Mesh | None = None) -> int:
    """Size of the tensor-parallel (`model`) axis; 1 when no mesh is active."""
    mesh = mesh if mesh is not None else _ACT_MESH.get()
    if mesh is None:
        return 1
    return dict(mesh.shape).get("model", 1)


def tp_shard_map(body, mesh: Mesh, in_specs, out_specs, axis: str = "model"):
    """Partial-manual ``shard_map`` over the tensor-parallel axis only.

    Other mesh axes (data/pod) stay under the auto partitioner, so callers
    can spell specs purely in terms of ``model``.  Used for Pallas kernels
    (which have no SPMD partitioning rules — each shard runs the unmodified
    kernel on its slice) and the MoE expert-parallel block."""
    if hasattr(jax, "shard_map"):  # jax >= 0.6 spelling
        return jax.shard_map(body, mesh=mesh, axis_names={axis},
                             in_specs=in_specs, out_specs=out_specs)
    from repro.core.torus import shard_map as _shmap
    auto = frozenset(mesh.axis_names) - {axis}
    return _shmap(body, mesh=mesh, auto=auto, check_rep=False,
                  in_specs=in_specs, out_specs=out_specs)


def batch_pspec(mesh: Mesh, batch_dim_divisor: int = 0) -> P:
    axes = tuple(a for a in BATCH_AXES if a in mesh.shape)
    return P(axes if len(axes) > 1 else (axes[0] if axes else None))


# ---------------------------------------------------------------------------
# Activation sharding constraints.  Without these, XLA's sharding propagation
# is free to replicate the batch across the data axis and turn the FSDP
# weight sharding into contraction-dim "tensor parallelism" — catastrophic
# (measured: 16x activation blow-up + TB-scale cross-data all-reduces on
# olmo-1b).  Model code calls ``constrain(x, logical_axes)`` at the residual
# stream and other anchor points; it is a no-op unless a mesh is active.
# ---------------------------------------------------------------------------

_ACT_MESH: contextvars.ContextVar = contextvars.ContextVar("act_mesh", default=None)
_ACT_PROFILE: contextvars.ContextVar = contextvars.ContextVar("act_profile",
                                                              default=None)


@contextlib.contextmanager
def activation_mesh(mesh: Mesh, profile: "ShardingProfile | None" = None):
    """Set while *tracing/lowering* (constraints are applied at trace time)."""
    tok = _ACT_MESH.set(mesh)
    tok2 = _ACT_PROFILE.set(profile)
    try:
        yield
    finally:
        _ACT_MESH.reset(tok)
        _ACT_PROFILE.reset(tok2)


def current_mesh() -> Mesh | None:
    return _ACT_MESH.get()


def _current_profile() -> "ShardingProfile":
    return _ACT_PROFILE.get() or ShardingProfile()


def constrain(x, axes: tuple):
    mesh = _ACT_MESH.get()
    if mesh is None or x is None:
        return x
    from repro.models.params import ParamSpec
    spec = resolve_pspec(ParamSpec(x.shape, axes), mesh, fsdp=False)
    # inside a shard_map manual region the ambient abstract mesh marks some
    # axes Manual; constraints there must target that mesh with the manual
    # axes dropped from the spec (they are already local)
    am = getattr(jax.sharding, "get_abstract_mesh", lambda: None)()
    manual = set()
    if am is not None and am.axis_names:
        manual = {n for n, t in zip(am.axis_names, am.axis_types)
                  if t == jax.sharding.AxisType.Manual}
    if manual:
        entries = []
        for e in spec:
            if isinstance(e, tuple):
                kept = tuple(a for a in e if a not in manual)
                e = kept if len(kept) > 1 else (kept[0] if kept else None)
            elif e in manual:
                e = None
            entries.append(e)
        return jax.lax.with_sharding_constraint(x, NamedSharding(am, P(*entries)))
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
