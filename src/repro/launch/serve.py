"""Production serving driver: continuous-batching request loop.

Streams a Poisson arrival process through the engine — requests are admitted
into pages of the shared KV pool as they free up (common prompt prefixes
share pages through the radix cache), so the decode batch stays full without
ever recompiling.

    PYTHONPATH=src python -m repro.launch.serve --arch gemma3-4b --reduced \
        --requests 16 --max-new 32 --rate 4
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config, reduce_config
from repro.models import model as M
from repro.serving import Engine, EngineConfig, bytes_tokenizer_encode


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="olmo-1b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.7)
    ap.add_argument("--rate", type=float, default=0.0,
                    help="Poisson arrival rate (req/s); 0 = all at once")
    ap.add_argument("--batch", type=int, default=8,
                    help="max concurrent sequences (decode batch)")
    ap.add_argument("--max-len", type=int, default=512)
    ap.add_argument("--page-size", type=int, default=64)
    ap.add_argument("--pages", type=int, default=None,
                    help="KV page-pool size (default: batch*max_len worth)")
    ap.add_argument("--no-prefix-cache", action="store_true",
                    help="disable radix prefix reuse")
    ap.add_argument("--chunk-tokens", type=int, default=None,
                    help="chunked-prefill budget: at most this many prompt "
                         "tokens per tick, run together with in-flight "
                         "decodes in one mixed step (default: whole-suffix "
                         "prefill)")
    ap.add_argument("--kernel-mode", default=None,
                    choices=["reference", "interpret", "pallas"],
                    help="route GEMMs/attention through the CGRA Pallas "
                         "kernels (default: config's kernel_mode)")
    ap.add_argument("--quant", default=None, choices=["none", "w8a8"],
                    help="w8a8: int8-quantize weights at load and serve "
                         "through the packed int8 GEMM kernels")
    ap.add_argument("--mesh", default=None, metavar="DxM",
                    help="serve mesh-sharded over the first D*M devices "
                         "(data x model, e.g. '1x8'; a bare 'M' means "
                         "model-parallel only).  Params/KV pools are placed "
                         "with NamedSharding; MoE configs route experts "
                         "across the model axis")
    ap.add_argument("--deadline", type=float, default=None, metavar="S",
                    help="per-request deadline in seconds (queueing + "
                         "execution); expired requests retire "
                         "FinishReason.DEADLINE")
    ap.add_argument("--max-queue", type=int, default=1024,
                    help="admission queue bound; past it requests finish "
                         "immediately as REJECTED with a retry_after_s hint")
    ap.add_argument("--preemption", default="off",
                    choices=["off", "recompute", "drop"],
                    help="page-pressure policy: 'recompute' admits on "
                         "prompt-only page reservations and preempts the "
                         "lowest-priority decode on exhaustion (requeue + "
                         "bit-identical recompute); 'drop' sheds the victim "
                         "with its partial output")
    ap.add_argument("--chaos", type=int, default=None, metavar="SEED",
                    help="attach a seeded ChaosInjector (transient "
                         "pool.alloc / runner.mixed faults + rare NaN "
                         "logits) to exercise the degraded paths")
    args = ap.parse_args()

    cfg = reduce_config(get_config(args.arch)) if args.reduced \
        else get_config(args.arch)
    params = M.init(cfg, jax.random.PRNGKey(0))
    chaos = None
    if args.chaos is not None:
        from repro.serving import ChaosInjector
        chaos = ChaosInjector(seed=args.chaos,
                              rates={"pool.alloc": 0.05,
                                     "runner.mixed": 0.05,
                                     "logits.nan": 0.01})
    eng = Engine(cfg, params, EngineConfig(
        max_len=args.max_len, max_batch=args.batch, page_size=args.page_size,
        n_pages=args.pages, prefix_cache=not args.no_prefix_cache,
        chunk_tokens=args.chunk_tokens, max_queue=args.max_queue,
        deadline_s=args.deadline, preemption=args.preemption,
        kernel_mode=args.kernel_mode, quant=args.quant, mesh=args.mesh),
        chaos=chaos)

    rng = np.random.RandomState(0)
    prompts = [bytes_tokenizer_encode(f"request {i}: " + "x" * rng.randint(4, 40),
                                      cfg.vocab_size)
               for i in range(args.requests)]

    results = []
    if args.rate > 0:  # streaming arrivals
        due = np.cumsum(rng.exponential(1.0 / args.rate, len(prompts)))
        t0, nxt = time.time(), 0
        while nxt < len(prompts) or eng.num_queued or eng.num_active:
            now = time.time() - t0
            while nxt < len(prompts) and now >= due[nxt]:
                eng.submit(prompts[nxt], args.max_new, args.temperature,
                           seed=nxt)
                nxt += 1
            if not (eng.num_queued or eng.num_active):
                time.sleep(min(0.01, max(0.0, due[nxt] - now)))  # idle: wait
                continue
            results.extend(eng.step())
    else:
        for i, p in enumerate(prompts):
            eng.submit(p, args.max_new, args.temperature, seed=i)
        results = eng.run()

    results.extend(eng.close())  # drain + reconcile the paging state
    stats = eng.stats
    ok = [r for r in results if r.ok]
    lat = sorted(r.latency_s for r in ok) or [0.0]
    p50 = lat[len(lat) // 2]
    p99 = lat[min(len(lat) - 1, int(len(lat) * 0.99))]
    print(f"arch={cfg.name} kernel_mode={eng.cfg.kernel_mode} "
          f"quant={eng.cfg.quant} requests={len(results)} ok={len(ok)} "
          f"batch={args.batch} pages={eng.pool.n_pages} "
          f"prefill={stats.prefill_s:.2f}s decode={stats.decode_s:.2f}s "
          f"throughput={stats.tokens_per_s:.1f} tok/s "
          f"prefix_hit={eng.prefix_hit_rate:.0%} "
          f"p50={p50:.2f}s p99={p99:.2f}s")
    if (stats.preempted or stats.rejected or stats.deadline_expired
            or stats.cancelled or stats.faults_isolated):
        print(f"degraded: preempted={stats.preempted} "
              f"rejected={stats.rejected} "
              f"deadline_expired={stats.deadline_expired} "
              f"cancelled={stats.cancelled} "
              f"faults_isolated={stats.faults_isolated}")


if __name__ == "__main__":
    main()
