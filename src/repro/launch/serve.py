"""Production serving driver: batched request loop over the Engine.

    PYTHONPATH=src python -m repro.launch.serve --arch gemma3-4b --reduced \
        --requests 8 --max-new 32
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.configs import get_config, reduce_config
from repro.models import model as M
from repro.serving.engine import Engine, bytes_tokenizer_encode


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="olmo-1b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.7)
    args = ap.parse_args()

    cfg = reduce_config(get_config(args.arch)) if args.reduced \
        else get_config(args.arch)
    params = M.init(cfg, jax.random.PRNGKey(0))
    eng = Engine(cfg, params)

    rng = np.random.RandomState(0)
    prompts = [bytes_tokenizer_encode(f"request {i}: " + "x" * rng.randint(4, 40),
                                      cfg.vocab_size)
               for i in range(args.requests)]
    out, stats = eng.generate(prompts, max_new=args.max_new,
                              temperature=args.temperature)
    print(f"arch={cfg.name} batch={len(prompts)} prefill={stats.prefill_s:.2f}s "
          f"decode={stats.decode_s:.2f}s throughput={stats.tokens_per_s:.1f} tok/s")


if __name__ == "__main__":
    main()
